"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                         total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    # (step+1): the first step must train, not idle at lr=0
    warm = peak_lr * (step + 1) / jnp.maximum(warmup_steps, 1)
    progress = (step - warmup_steps) / jnp.maximum(
        total_steps - warmup_steps, 1)
    progress = jnp.clip(progress, 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak_lr)
