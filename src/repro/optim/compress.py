"""Int8 error-feedback gradient compression for slow inter-pod links.

Cross-pod gradient all-reduce is the dominant multi-pod collective
(§Roofline); quantizing the cross-pod leg to int8 cuts its bytes 4x
(vs f32 accumulators; 2x vs bf16).  Error feedback keeps the scheme
unbiased over time: the quantization residual is carried and added to
the next step's gradient, so SGD-style convergence guarantees hold
(Seide et al.; Karimireddy et al.).

``compress_decompress`` is the numerical core (quantize -> [transport]
-> dequantize, residual out).  In the trainer it wraps the gradient
*before* the pod-axis psum inside shard_map (launch/train.py); here it
is transport-agnostic so tests can assert the error-feedback invariant
exactly.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: object        # pytree of f32 residuals, zeros at init


def compression_init(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, state: CompressionState
                        ) -> Tuple[object, CompressionState]:
    """Quantize (grad + residual) to int8, return dequantized grads and
    the new residuals.  The int8 payload is what crosses the pod link."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = int8_quantize(g)
        deq = int8_dequantize(q, scale)
        return deq, g - deq

    out = jax.tree.map(one, grads, state.residual)
    deq = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda o: o[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, CompressionState(residual=res)
