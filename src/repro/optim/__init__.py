"""Optimizer substrate: AdamW, schedules, clipping, compression."""

from .adamw import AdamWState, adamw_init, adamw_update, opt_state_specs
from .compress import (CompressionState, compress_decompress,
                       compression_init, int8_quantize, int8_dequantize)
from .schedules import constant, linear_warmup_cosine

__all__ = ["AdamWState", "CompressionState", "adamw_init", "adamw_update",
           "compress_decompress", "compression_init", "constant",
           "int8_dequantize", "int8_quantize", "linear_warmup_cosine",
           "opt_state_specs"]
