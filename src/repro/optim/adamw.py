"""AdamW with decoupled weight decay, f32 moments over bf16 params.

Functional: ``adamw_init(params) -> state``, ``adamw_update(grads,
state, params, lr, ...) -> (new_params, new_state)``.  Moments inherit
the parameter PartitionSpecs (``opt_state_specs``), so FSDP shards
optimizer state exactly like parameters -- the ZeRO-3 layout.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object          # first moment pytree (f32)
    nu: object          # second moment pytree (f32)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay and p.ndim >= 2:       # no decay on norms/biases
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)


def opt_state_specs(param_spec_tree) -> AdamWState:
    """Moments shard exactly like their parameters (ZeRO-3)."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), mu=param_spec_tree, nu=param_spec_tree)
