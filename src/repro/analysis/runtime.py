"""PlaneCheck runtime sanitizers: recompile counters + transfer guard.

The static passes prove properties of the source; this thin layer
checks the two invariants that only manifest at run time:

* **Recompile counters** -- :func:`record_trace` is called *inside*
  jitted function bodies, so it executes exactly once per trace (Python
  in a traced body runs at trace time only).  A hot path that silently
  retraces -- a non-hashable static arg, a shape drifting per call --
  shows up as a count > 1 for the same key, with no dependence on any
  version-fragile jit-cache introspection API.
  ``benchmarks/lab_bench.py --smoke`` and the pytest sanitizer hooks
  assert one executable per counter key from these counts, so every
  call site must key on the *full* specialization its executable cache
  uses (shapes plus static args/devices), not a projection of it.

* **Transfer guard** -- :func:`dispatch_guard` wraps the sweep's chunk
  dispatch loop in ``jax.transfer_guard_host_to_device("disallow")``
  when sanitizers are enabled, so an accidental per-chunk host->device
  transfer (the regression class PR 3 hand-audited) raises instead of
  silently serializing every dispatch.

Both are no-ops unless ``PLANECHECK_SANITIZERS`` is set to a truthy
value (``1``/``true``/``on``), so production and benchmark hot paths
pay nothing.  This module must stay importable without jax -- jax is
imported lazily inside :func:`dispatch_guard` only.
"""

from __future__ import annotations

import contextlib
import os
import threading
from typing import Dict, Optional, Tuple

_ENV_VAR = "PLANECHECK_SANITIZERS"

_counts_lock = threading.Lock()
_counts: Dict[Tuple[str, Tuple[Tuple[str, object], ...]], int] = {}


def sanitizers_enabled() -> bool:
    """Are the runtime sanitizers switched on (``PLANECHECK_SANITIZERS``)?"""
    return os.environ.get(_ENV_VAR, "").strip().lower() in (
        "1", "true", "yes", "on")


def record_trace(name: str, **dims) -> None:
    """Count one tracing of the call site keyed by ``(name, dims)``.

    Call from inside a jitted/scanned function body with *concrete*
    dims (shapes, flags -- never traced values); each retrace of the
    surrounding program increments the key once.  A no-op with
    sanitizers off, so a long-lived production process never grows the
    count dict (``plane.fused_step`` records one key per fleet size).
    The flag is read at *trace* time: enable it before the first
    dispatch (as the CI env, the pytest fixture, and ``lab_bench
    --smoke`` all do), because an executable compiled while it was off
    sits in the jit cache and is never re-traced, hence never counted.
    """
    if not sanitizers_enabled():
        return
    key = (name, tuple(sorted(dims.items())))
    with _counts_lock:
        _counts[key] = _counts.get(key, 0) + 1


def trace_counts(prefix: Optional[str] = None) -> Dict[str, int]:
    """Snapshot of recompile counts, formatted ``name{k=v,...}`` -> n."""
    with _counts_lock:
        items = list(_counts.items())
    out = {}
    for (name, dims), n in items:
        if prefix is not None and not name.startswith(prefix):
            continue
        label = name
        if dims:
            label += "{" + ",".join(f"{k}={v}" for k, v in dims) + "}"
        out[label] = n
    return out


def reset_trace_counts() -> None:
    with _counts_lock:
        _counts.clear()


def excess_traces(prefix: str) -> Dict[str, int]:
    """Keys under ``prefix`` traced more than once (retrace suspects)."""
    return {k: n for k, n in trace_counts(prefix).items() if n > 1}


@contextlib.contextmanager
def dispatch_guard():
    """Disallow implicit transfers around a dispatch loop (when enabled).

    With sanitizers off this is a free no-op; with them on, any
    implicit host<->device transfer inside the block raises.  Callers
    must stage every operand device-side (and warm the executable)
    before entering.
    """
    if not sanitizers_enabled():
        yield
        return
    import jax
    # Host->device only: the sharded sweep legitimately reshards
    # replicated operands across the mesh (device-to-device) at
    # dispatch, and results come back device-to-host.  The regression
    # class this guards against is per-chunk host staging.
    with jax.transfer_guard_host_to_device("disallow"):
        yield
