"""``python -m repro.analysis`` -- the PlaneCheck CLI.

Usage::

    python -m repro.analysis src/                 # report everything
    python -m repro.analysis --check src/         # CI gate: exit 1 on
                                                  # non-baselined findings
    python -m repro.analysis --write-baseline src/  # accept current state
    python -m repro.analysis --json src/          # machine-readable

The baseline lives at ``PLANECHECK_BASELINE.json`` (repo root) unless
``--baseline`` points elsewhere.  Every entry must carry a one-line
justification; ``--check`` also fails on unjustified entries, and
warns on stale ones (entries that no longer match any finding).
"""

from __future__ import annotations

import argparse
import json
import sys

from . import Baseline, RULES, run


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="PlaneCheck: jit-hot-path + lock-discipline analyzer")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to analyze "
                             "(default: src)")
    parser.add_argument("--check", action="store_true",
                        help="exit 1 on any non-baselined finding "
                             "(the CI gate)")
    parser.add_argument("--baseline", default="PLANECHECK_BASELINE.json",
                        help="baseline file (default: "
                             "PLANECHECK_BASELINE.json)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write all current findings to the baseline "
                             "(justifications left as TODO)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit findings as JSON")
    parser.add_argument("--rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    paths = args.paths or ["src"]
    baseline = Baseline.load(args.baseline)
    errors = baseline.validate()
    findings, new = run(paths, baseline)

    if args.write_baseline:
        Baseline.write(args.baseline, findings)
        print(f"wrote {len(findings)} entries to {args.baseline} "
              "(fill in the justifications)")
        return 0

    if args.as_json:
        print(json.dumps({
            "findings": [f.to_dict() for f in findings],
            "new": [f.to_dict() for f in new],
            "baseline_errors": errors,
        }, indent=2))
    else:
        for f in new:
            print(f.format())
        n_base = len(findings) - len(new)
        print(f"planecheck: {len(findings)} finding(s), "
              f"{n_base} baselined, {len(new)} new", file=sys.stderr)
        for err in errors:
            print(f"planecheck: baseline error: {err}", file=sys.stderr)
        for e in baseline.stale():
            print(f"planecheck: warning: stale baseline entry "
                  f"{e.get('rule')} {e.get('file')}:{e.get('symbol')}",
                  file=sys.stderr)

    if args.check and (new or errors):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
