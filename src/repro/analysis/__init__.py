"""PlaneCheck: static analysis for the repo's two fragile invariants.

The jitted sweep hot path must never silently sync, retrace, or
transfer (PR 3 measured 40x+ XLA CPU regressions when it does), and
the MemoryPlane's lock/epoch protocol must never tear a control
interval (PR 5's swap machinery).  Both were guarded by convention and
benchmarks; this package makes them machine-checked:

* :mod:`.tracelint` -- walks functions reachable from ``jax.jit`` /
  ``lax.scan`` / ``shard_map`` call sites and flags host syncs, host
  casts, Python control flow on traced values, numpy calls on traced
  arrays, float64 promotion in the streaming accumulators, in-jit
  sort/scatter, and jit-in-loop retrace risk (rules ``PC-T001`` ..
  ``PC-T007``).
* :mod:`.locklint` -- extracts the lock-acquisition graph plus
  ``# guarded-by: <lock>`` field annotations and reports lock-order
  inversions, guarded fields mutated without their lock, and blocking
  work performed while holding a lock (rules ``PC-L001`` ..
  ``PC-L003``).
* :mod:`.runtime` -- the thin runtime-sanitizer layer: trace-time
  recompile counters and a ``jax.transfer_guard`` context for the
  sweep dispatch loop, both enabled by ``PLANECHECK_SANITIZERS=1``.

Pure stdlib (``ast``); importing this package never imports jax.  Run
the CLI with ``python -m repro.analysis --check src/`` -- findings not
listed in ``PLANECHECK_BASELINE.json`` (each entry justified) fail the
gate.  Suppress a single line with ``# planecheck: ignore[RULE]``.
"""

from .findings import Baseline, Finding, RULES
from .locklint import analyze_locks
from .tracelint import analyze_traced

__all__ = [
    "Baseline",
    "Finding",
    "RULES",
    "analyze_locks",
    "analyze_traced",
    "run",
]


def run(paths, baseline=None):
    """Analyze ``paths`` with both pass families.

    Returns ``(findings, new)`` where ``new`` is the subset not covered
    by ``baseline`` (all of them when no baseline is given).
    """
    findings = sorted(
        analyze_traced(paths) + analyze_locks(paths),
        key=lambda f: (f.file, f.line, f.rule))
    if baseline is None:
        return findings, list(findings)
    return findings, [f for f in findings if not baseline.covers(f)]
