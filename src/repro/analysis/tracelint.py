"""TraceLint: jit-hot-path rules over the reachable traced call graph.

Three phases, all pure ``ast``:

1. **Collection** -- parse every module under the given paths, build
   per-module alias maps (``import jax.numpy as jnp``, relative
   ``from ..core.control import vectorized_step``, module-level
   fallback assignments like ``_shard_map = jax.shard_map``) and a
   registry of every function/method/lambda with its nesting structure.
2. **Seeding** -- find tracing entry points: ``@jax.jit`` /
   ``@functools.partial(jax.jit, static_argnames=...)`` decorators and
   callables handed to ``jax.jit`` / ``jax.vmap`` / ``jax.lax.scan`` /
   ``fori_loop`` / ``while_loop`` / ``cond`` / ``shard_map`` /
   ``pallas_call`` (including through a local ``functools.partial``
   binding, whose bound arguments become static).
3. **Taint fixpoint** -- walk each traced function with a value-taint
   environment: positional parameters are traced, keyword-only and
   ``static_argnames`` parameters are static (the repo's calling
   convention), and call sites propagate the *actual* argument taint
   into resolvable callees until the per-parameter taint stabilizes.
   ``.shape``/``.dtype``-style attributes, ``isinstance``/``len``, and
   ``is None`` comparisons launder taint (they are static under
   tracing); nested functions inherit a snapshot of the enclosing
   environment as closure taint.  The final pass emits findings.

The taint discipline is what keeps the rules quiet on the real tree:
``float(cache.reuse_skew)`` in the sweep's traced body is fine (the
cache spec is a trace-time constant), while ``float(r)`` on the scanned
utilization would fire PC-T002.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding, relpath

# Entry points that trace their N-th positional argument as jax code.
_TRACED_ARG_POS: Dict[str, Tuple[int, ...]] = {
    "jax.jit": (0,),
    "jax.vmap": (0,),
    "jax.pmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.fori_loop": (2,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.cond": (1, 2),
    "jax.shard_map": (0,),
    "jax.experimental.shard_map.shard_map": (0,),
    "jax.experimental.pallas.pallas_call": (0,),
}

# Attributes of a traced value that are static under tracing.
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "itemsize"}

# Builtins whose result is always static (trace-time metadata).
_STATIC_FUNCS = {"isinstance", "len", "type", "hasattr", "callable",
                 "id", "range", "repr", "issubclass"}

# Builtins that concretize their argument (host round trip under jit).
_CAST_FUNCS = {"float", "int", "bool"}
_COERCE_FUNCS = {"min", "max", "sum", "sorted", "any", "all", "list",
                 "tuple"}

_SYNC_METHODS = {"item", "tolist", "block_until_ready", "copy_to_host_async"}

_SORT_FAMILY = {"sort", "argsort", "lexsort", "quantile", "nanquantile",
                "percentile", "nanpercentile", "median", "nanmedian",
                "unique", "msort", "partition", "argpartition"}

_F64_NAMES = {"numpy.float64", "jax.numpy.float64", "numpy.double"}

_IGNORE_RE = re.compile(r"#\s*planecheck:\s*ignore\[([A-Z0-9-]+)\]")


# ---------------------------------------------------------------------------
# Module / function registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FuncInfo:
    module: "ModuleInfo"
    qualname: str
    node: ast.AST                        # FunctionDef | Lambda
    cls_name: Optional[str] = None
    parent: Optional["FuncInfo"] = None
    traced: bool = False
    is_seed: bool = False
    seed_reason: str = ""
    static_params: Set[str] = dataclasses.field(default_factory=set)
    param_taint: Dict[str, bool] = dataclasses.field(default_factory=dict)
    closure_taint: Set[str] = dataclasses.field(default_factory=set)
    nested: Dict[str, "FuncInfo"] = dataclasses.field(default_factory=dict)

    @property
    def positional_params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in list(a.posonlyargs) + list(a.args)]

    @property
    def kwonly_params(self) -> List[str]:
        return [p.arg for p in self.node.args.kwonlyargs]

    @property
    def all_params(self) -> List[str]:
        names = self.positional_params + self.kwonly_params
        if self.node.args.vararg:
            names.append(self.node.args.vararg.arg)
        if self.node.args.kwarg:
            names.append(self.node.args.kwarg.arg)
        return names

    def seed_taint(self) -> Dict[str, bool]:
        """Initial per-parameter taint for a tracing entry point."""
        taint = {}
        for name in self.positional_params:
            taint[name] = name not in self.static_params
        for name in self.kwonly_params:
            taint[name] = False
        if self.node.args.vararg:
            taint[self.node.args.vararg.arg] = True
        if self.node.args.kwarg:
            taint[self.node.args.kwarg.arg] = False
        # Methods: the bound instance is a static container.
        if self.cls_name and self.positional_params[:1] == ["self"]:
            taint["self"] = False
        return taint


@dataclasses.dataclass
class ModuleInfo:
    name: str                           # dotted module name
    path: str                           # filesystem path
    tree: ast.Module
    lines: List[str]
    aliases: Dict[str, str] = dataclasses.field(default_factory=dict)
    top_funcs: Dict[str, FuncInfo] = dataclasses.field(default_factory=dict)
    class_methods: Dict[str, Dict[str, FuncInfo]] = dataclasses.field(
        default_factory=dict)
    all_funcs: List[FuncInfo] = dataclasses.field(default_factory=list)
    by_node: Dict[int, FuncInfo] = dataclasses.field(default_factory=dict)

    def line_has_ignore(self, lineno: int, rule: str) -> bool:
        for ln in (lineno, lineno - 1):
            if 1 <= ln <= len(self.lines):
                m = _IGNORE_RE.search(self.lines[ln - 1])
                if m and m.group(1) in (rule, "ALL"):
                    return True
        return False


def _dotted(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _module_name_for(path: str) -> str:
    """Dotted module name from the path, walking up ``__init__.py`` dirs."""
    path = os.path.abspath(path)
    parts = [os.path.splitext(os.path.basename(path))[0]]
    d = os.path.dirname(path)
    while os.path.exists(os.path.join(d, "__init__.py")):
        parts.append(os.path.basename(d))
        d = os.path.dirname(d)
    if parts[0] == "__init__":
        parts = parts[1:]
    return ".".join(reversed(parts)) or os.path.basename(path)


def _collect_aliases(mod: ModuleInfo) -> None:
    pkg_parts = mod.name.split(".")

    def visit(stmts):
        for s in stmts:
            if isinstance(s, ast.Import):
                for a in s.names:
                    mod.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(s, ast.ImportFrom):
                if s.level:
                    base = pkg_parts[:-s.level] if s.level <= len(pkg_parts) \
                        else []
                    target = ".".join(base + ([s.module] if s.module else []))
                else:
                    target = s.module or ""
                for a in s.names:
                    if a.name == "*":
                        continue
                    mod.aliases[a.asname or a.name] = (
                        f"{target}.{a.name}" if target else a.name)
            elif isinstance(s, ast.Assign) and len(s.targets) == 1 and \
                    isinstance(s.targets[0], ast.Name):
                d = _dotted(s.value)
                if d:
                    resolved = resolve_dotted(mod, d)
                    if resolved:
                        mod.aliases[s.targets[0].id] = resolved
            elif isinstance(s, (ast.Try, ast.If)):
                visit(getattr(s, "body", []))
                visit(getattr(s, "orelse", []))
                for h in getattr(s, "handlers", []):
                    visit(h.body)
                visit(getattr(s, "finalbody", []))

    visit(mod.tree.body)


def resolve_dotted(mod: ModuleInfo, dotted: Optional[str]) -> Optional[str]:
    """Expand the leading component of ``dotted`` through the alias map."""
    if not dotted:
        return None
    head, _, rest = dotted.partition(".")
    target = mod.aliases.get(head, head)
    return f"{target}.{rest}" if rest else target


class _Collector(ast.NodeVisitor):
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.func_stack: List[FuncInfo] = []
        self.cls_stack: List[str] = []

    def _register(self, node, name: str) -> FuncInfo:
        parent = self.func_stack[-1] if self.func_stack else None
        cls = self.cls_stack[-1] if (self.cls_stack and not parent) else None
        qual = name
        if parent is not None:
            qual = f"{parent.qualname}.{name}"
        elif cls is not None:
            qual = f"{cls}.{name}"
        fi = FuncInfo(module=self.mod, qualname=qual, node=node,
                      cls_name=cls, parent=parent)
        self.mod.all_funcs.append(fi)
        self.mod.by_node[id(node)] = fi
        if parent is not None:
            parent.nested[name] = fi
        elif cls is not None:
            self.mod.class_methods.setdefault(cls, {})[name] = fi
        else:
            self.mod.top_funcs[name] = fi
        return fi

    def visit_ClassDef(self, node):
        self.cls_stack.append(node.name)
        self.generic_visit(node)
        self.cls_stack.pop()

    def _visit_func(self, node, name):
        fi = self._register(node, name)
        self.func_stack.append(fi)
        self.generic_visit(node)
        self.func_stack.pop()

    def visit_FunctionDef(self, node):
        self._visit_func(node, node.name)

    def visit_AsyncFunctionDef(self, node):
        self._visit_func(node, node.name)

    def visit_Lambda(self, node):
        self._visit_func(node, f"<lambda:{node.lineno}>")


def load_module(path: str) -> Optional[ModuleInfo]:
    try:
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        tree = ast.parse(src, filename=path)
    except (OSError, SyntaxError):
        return None
    mod = ModuleInfo(name=_module_name_for(path), path=path, tree=tree,
                     lines=src.splitlines())
    _collect_aliases(mod)
    _Collector(mod).visit(tree)
    return mod


# ---------------------------------------------------------------------------
# The analysis engine
# ---------------------------------------------------------------------------

class TraceLint:
    def __init__(self, paths: Sequence[str], root: Optional[str] = None):
        self.root = root or os.getcwd()
        self.modules: Dict[str, ModuleInfo] = {}
        for path in _python_files(paths):
            mod = load_module(path)
            if mod is not None:
                self.modules[mod.name] = mod
        self.findings: List[Finding] = []
        self._changed = False

    # -- resolution ---------------------------------------------------------
    def resolve_callable(self, mod: ModuleInfo, fi: Optional[FuncInfo],
                         node: ast.AST,
                         local_bindings: Optional[dict] = None
                         ) -> Optional[Tuple[FuncInfo, Set[str]]]:
        """Resolve an expression to ``(FuncInfo, static_param_names)``."""
        if isinstance(node, ast.Lambda):
            got = mod.by_node.get(id(node))
            return (got, set()) if got else None
        if isinstance(node, ast.Call):
            # functools.partial(f, ...) -- bound args become static
            fname = resolve_dotted(mod, _dotted(node.func))
            if fname == "functools.partial" and node.args:
                inner = self.resolve_callable(mod, fi, node.args[0],
                                              local_bindings)
                if inner is None:
                    return None
                target, statics = inner
                statics = set(statics)
                pos = target.positional_params
                for i in range(1, len(node.args)):
                    if i - 1 < len(pos):
                        statics.add(pos[i - 1])
                statics.update(kw.arg for kw in node.keywords if kw.arg)
                return target, statics
            return None
        dotted = _dotted(node)
        if dotted is None:
            return None
        if local_bindings and dotted in local_bindings:
            return local_bindings[dotted]
        if "." not in dotted:
            got = self._lookup_name(mod, fi, dotted)
            return (got, set()) if got else None
        # self.method / alias.func
        head, _, rest = dotted.partition(".")
        if head == "self" and fi is not None and fi.cls_name and \
                "." not in rest:
            got = mod.class_methods.get(fi.cls_name, {}).get(rest)
            return (got, set()) if got else None
        resolved = resolve_dotted(mod, dotted)
        if resolved:
            mmod, _, func = resolved.rpartition(".")
            target = self.modules.get(mmod)
            if target and func in target.top_funcs:
                return target.top_funcs[func], set()
        return None

    def _lookup_name(self, mod: ModuleInfo, fi: Optional[FuncInfo],
                     name: str) -> Optional[FuncInfo]:
        f = fi
        while f is not None:
            if name in f.nested:
                return f.nested[name]
            f = f.parent
        if fi is not None and fi.cls_name and fi.parent is None:
            pass  # bare names inside methods do not resolve to methods
        if name in mod.top_funcs:
            return mod.top_funcs[name]
        target = mod.aliases.get(name)
        if target:
            mmod, _, func = target.rpartition(".")
            tm = self.modules.get(mmod)
            if tm and func in tm.top_funcs:
                return tm.top_funcs[func]
        return None

    # -- seeding ------------------------------------------------------------
    def seed(self) -> None:
        for mod in self.modules.values():
            for fi in mod.all_funcs:
                self._seed_decorators(mod, fi)
            for fi in mod.all_funcs:
                self._seed_calls(mod, fi, fi.node, {})
            self._seed_calls(mod, None, mod.tree, {})

    def _mark_seed(self, fi: FuncInfo, reason: str,
                   statics: Set[str] = frozenset()) -> None:
        fi.is_seed = True
        fi.seed_reason = fi.seed_reason or reason
        fi.static_params |= set(statics)
        fi.traced = True
        for name, tainted in fi.seed_taint().items():
            if tainted:
                fi.param_taint[name] = True

    def _seed_decorators(self, mod: ModuleInfo, fi: FuncInfo) -> None:
        for dec in getattr(fi.node, "decorator_list", []):
            statics: Set[str] = set()
            if isinstance(dec, ast.Call):
                fname = resolve_dotted(mod, _dotted(dec.func))
                if fname == "functools.partial" and dec.args:
                    inner = resolve_dotted(mod, _dotted(dec.args[0]))
                    if inner != "jax.jit":
                        continue
                elif fname != "jax.jit":
                    continue
                statics = _static_argnames(dec, fi)
                self._mark_seed(fi, "jax.jit decorator", statics)
            else:
                fname = resolve_dotted(mod, _dotted(dec))
                if fname == "jax.jit":
                    self._mark_seed(fi, "jax.jit decorator")

    def _seed_calls(self, mod: ModuleInfo, fi: Optional[FuncInfo],
                    scope_node: ast.AST, bindings: dict) -> None:
        """Walk one scope (not into nested defs) seeding wrapper calls."""
        for node in _walk_scope(scope_node):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                resolved = self.resolve_callable(mod, fi, node.value,
                                                 bindings)
                if resolved is not None:
                    bindings[node.targets[0].id] = resolved
            if not isinstance(node, ast.Call):
                continue
            fname = resolve_dotted(mod, _dotted(node.func))
            positions = _TRACED_ARG_POS.get(fname or "")
            if positions is None:
                continue
            statics = _static_argnames(node, None)
            for pos in positions:
                if pos >= len(node.args):
                    continue
                resolved = self.resolve_callable(mod, fi, node.args[pos],
                                                 bindings)
                if resolved is None:
                    continue
                target, bound_statics = resolved
                own = _static_argnames(node, target) if fname == "jax.jit" \
                    else statics
                self._mark_seed(target, f"{fname} call site",
                                bound_statics | own)
        # Recurse into nested function scopes with a copy of the bindings
        for child in _nested_defs(scope_node):
            child_fi = mod.by_node.get(id(child))
            self._seed_calls(mod, child_fi, child, dict(bindings))

    # -- fixpoint -----------------------------------------------------------
    def run(self) -> List[Finding]:
        self.seed()
        for _ in range(8):
            self._changed = False
            for mod in self.modules.values():
                for fi in mod.all_funcs:
                    if fi.traced:
                        _FunctionWalker(self, fi, emit=False).walk()
            if not self._changed:
                break
        for mod in self.modules.values():
            for fi in mod.all_funcs:
                if fi.traced:
                    _FunctionWalker(self, fi, emit=True).walk()
                else:
                    _LoopJitScan(self, fi).walk()
        return self.findings

    # -- taint propagation into callees --------------------------------------
    def propagate_call(self, callee: FuncInfo, node: ast.Call,
                       arg_taints: List[bool],
                       kw_taints: Dict[str, bool]) -> None:
        if not callee.traced:
            callee.traced = True
            self._changed = True
        pos = callee.positional_params
        skip = 1 if (callee.cls_name and pos[:1] == ["self"] and
                     isinstance(node.func, ast.Attribute)) else 0
        for i, taint in enumerate(arg_taints):
            idx = i + skip
            if idx < len(pos):
                self._taint_param(callee, pos[idx], taint)
            elif callee.node.args.vararg:
                self._taint_param(callee, callee.node.args.vararg.arg, taint)
        for name, taint in kw_taints.items():
            if name in callee.all_params:
                self._taint_param(callee, name, taint)

    def _taint_param(self, fi: FuncInfo, name: str, taint: bool) -> None:
        if taint and not fi.param_taint.get(name):
            fi.param_taint[name] = True
            self._changed = True

    def report(self, fi: FuncInfo, node: ast.AST, rule: str, message: str,
               hint: str = "") -> None:
        line = getattr(node, "lineno", 1)
        if fi.module.line_has_ignore(line, rule):
            return
        f = Finding(
            rule=rule, file=relpath(fi.module.path, self.root), line=line,
            symbol=fi.qualname, message=message, hint=hint)
        if f not in self.findings:
            self.findings.append(f)


def _static_argnames(call: ast.Call, fi: Optional[FuncInfo]) -> Set[str]:
    statics: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            statics |= _const_strs(kw.value)
        elif kw.arg == "static_argnums" and fi is not None:
            pos = fi.positional_params
            for idx in _const_ints(kw.value):
                if 0 <= idx < len(pos):
                    statics.add(pos[idx])
    return statics


def _const_strs(node: ast.AST) -> Set[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out = set()
        for e in node.elts:
            out |= _const_strs(e)
        return out
    return set()


def _const_ints(node: ast.AST) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            out.extend(_const_ints(e))
        return out
    return []


def _walk_scope(node: ast.AST):
    """Yield nodes of one function/module scope in document order,
    not entering nested defs (binding-before-use matters for the
    ``fn = partial(...); jax.jit(fn)`` idiom)."""
    for n in ast.iter_child_nodes(node):
        yield n
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda, ast.ClassDef)):
            continue
        yield from _walk_scope(n)


def _nested_defs(node: ast.AST):
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            yield n
            continue
        if isinstance(n, ast.ClassDef):
            continue
        stack.extend(ast.iter_child_nodes(n))


def _python_files(paths: Sequence[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for base, dirs, files in os.walk(p):
            dirs[:] = [d for d in dirs
                       if d not in ("__pycache__", ".git", ".tmp")]
            out.extend(os.path.join(base, f) for f in sorted(files)
                       if f.endswith(".py"))
    return out


# ---------------------------------------------------------------------------
# Per-function taint walk
# ---------------------------------------------------------------------------

class _LoopJitScan:
    """PC-T007 only, for host-side (untraced) functions."""

    def __init__(self, engine: TraceLint, fi: FuncInfo):
        self.engine = engine
        self.fi = fi

    def walk(self) -> None:
        mod = self.fi.module
        for node in _walk_scope(self.fi.node):
            if not isinstance(node, (ast.For, ast.While)):
                continue
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef, ast.Lambda)):
                    continue
                if isinstance(sub, ast.Call) and resolve_dotted(
                        mod, _dotted(sub.func)) == "jax.jit":
                    self.engine.report(
                        self.fi, sub, "PC-T007",
                        "jax.jit constructed inside a loop body builds a "
                        "fresh executable (and cache entry) per iteration",
                        hint="hoist the jit (or an lru_cached builder) out "
                             "of the loop")


class _FunctionWalker:
    def __init__(self, engine: TraceLint, fi: FuncInfo, emit: bool):
        self.engine = engine
        self.fi = fi
        self.mod = fi.module
        self.emit = emit
        self.loop_depth = 0
        self.env: Dict[str, bool] = {}
        for name in fi.all_params:
            self.env[name] = bool(fi.param_taint.get(name))
        if fi.is_seed:
            for name, t in fi.seed_taint().items():
                if t:
                    self.env[name] = True
        for name in fi.closure_taint:
            self.env.setdefault(name, True)

    # -- driver -------------------------------------------------------------
    def walk(self) -> None:
        node = self.fi.node
        if isinstance(node, ast.Lambda):
            self.ev(node.body)
            return
        self.block(node.body)

    def block(self, stmts) -> None:
        for s in stmts:
            self.stmt(s)

    # -- statements ---------------------------------------------------------
    def stmt(self, s: ast.stmt) -> None:
        if isinstance(s, ast.Assign):
            taint = self.ev(s.value)
            for t in s.targets:
                self.assign(t, taint, s.value)
        elif isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self.assign(s.target, self.ev(s.value), s.value)
        elif isinstance(s, ast.AugAssign):
            taint = self.ev(s.value)
            if isinstance(s.target, ast.Name):
                self.env[s.target.id] = self.env.get(s.target.id,
                                                     False) or taint
        elif isinstance(s, ast.Expr):
            self.ev(s.value)
        elif isinstance(s, ast.Return):
            if s.value is not None:
                self.ev(s.value)
        elif isinstance(s, (ast.If, ast.While)):
            if self.ev(s.test):
                self.flag_branch(s)
            if isinstance(s, ast.While):
                self.loop_depth += 1
            self.block(s.body)
            self.block(s.orelse)
            if isinstance(s, ast.While):
                self.loop_depth -= 1
        elif isinstance(s, ast.For):
            self.assign(s.target, self.ev(s.iter), None)
            self.loop_depth += 1
            self.block(s.body)
            self.block(s.orelse)
            self.loop_depth -= 1
        elif isinstance(s, ast.Assert):
            if self.ev(s.test):
                self.flag_branch(s)
            if s.msg is not None:
                self.ev(s.msg)
        elif isinstance(s, ast.With):
            for item in s.items:
                taint = self.ev(item.context_expr)
                if item.optional_vars is not None:
                    self.assign(item.optional_vars, taint, None)
            self.block(s.body)
        elif isinstance(s, ast.Try):
            self.block(s.body)
            for h in s.handlers:
                self.block(h.body)
            self.block(s.orelse)
            self.block(s.finalbody)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            nested = self.mod.by_node.get(id(s.node if False else s))
            if nested is not None:
                snap = {n for n, t in self.env.items() if t}
                if not snap <= nested.closure_taint:
                    nested.closure_taint |= snap
                    self.engine._changed = True
        elif isinstance(s, ast.Raise):
            if s.exc is not None:
                self.ev(s.exc)
        elif isinstance(s, ast.Delete):
            pass
        # Pass/Break/Continue/Import/Global/Nonlocal: nothing to do.

    def flag_branch(self, node: ast.stmt) -> None:
        if not self.emit:
            return
        kind = {ast.If: "if", ast.While: "while",
                ast.Assert: "assert"}.get(type(node), "branch")
        self.engine.report(
            self.fi, node, "PC-T003",
            f"Python `{kind}` on a traced value concretizes it at trace "
            "time (ConcretizationTypeError under jit, host sync otherwise)",
            hint="use jnp.where / lax.cond, or hoist the decision to a "
                 "static (keyword-only) argument")

    def assign(self, target: ast.AST, taint: bool,
               value: Optional[ast.AST]) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = taint
        elif isinstance(target, (ast.Tuple, ast.List)):
            if isinstance(value, (ast.Tuple, ast.List)) and \
                    len(value.elts) == len(target.elts):
                for t, v in zip(target.elts, value.elts):
                    self.assign(t, self.ev(v), v)
            else:
                for t in target.elts:
                    self.assign(t, taint, None)
        elif isinstance(target, ast.Starred):
            self.assign(target.value, taint, None)
        # Attribute / Subscript stores don't enter the name environment.

    # -- expressions ---------------------------------------------------------
    def ev(self, node: Optional[ast.AST]) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return self.env.get(node.id, False)
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            base = self.ev(node.value)
            if node.attr in _STATIC_ATTRS:
                return False
            return base
        if isinstance(node, ast.Subscript):
            return self.ev(node.value) or self.ev(node.slice)
        if isinstance(node, ast.Slice):
            return (self.ev(node.lower) or self.ev(node.upper)
                    or self.ev(node.step))
        if isinstance(node, ast.BinOp):
            return self.ev(node.left) or self.ev(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.ev(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.ev(v) for v in node.values)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            # `"key" in pytree` tests trace-time dict structure, not data
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops) \
                    and isinstance(node.left, ast.Constant) and \
                    isinstance(node.left.value, str):
                return False
            return self.ev(node.left) or any(self.ev(c)
                                             for c in node.comparators)
        if isinstance(node, ast.IfExp):
            if self.ev(node.test) and self.emit:
                self.engine.report(
                    self.fi, node, "PC-T003",
                    "ternary on a traced value concretizes it at trace time",
                    hint="use jnp.where")
            return self.ev(node.body) or self.ev(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.ev(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(self.ev(v) for v in list(node.keys) +
                       list(node.values) if v is not None)
        if isinstance(node, ast.Starred):
            return self.ev(node.value)
        if isinstance(node, ast.Lambda):
            return False
        if isinstance(node, ast.NamedExpr):
            taint = self.ev(node.value)
            self.assign(node.target, taint, node.value)
            return taint
        if isinstance(node, ast.Call):
            return self.call(node)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            for gen in node.generators:
                self.assign(gen.target, self.ev(gen.iter), None)
            if isinstance(node, ast.DictComp):
                return self.ev(node.key) or self.ev(node.value)
            return self.ev(node.elt)
        if isinstance(node, ast.JoinedStr):
            return False
        if isinstance(node, ast.Await):
            return self.ev(node.value)
        return False

    # -- calls ---------------------------------------------------------------
    def call(self, node: ast.Call) -> bool:
        arg_taints = [self.ev(a.value if isinstance(a, ast.Starred) else a)
                      for a in node.args]
        kw_taints = {kw.arg: self.ev(kw.value) for kw in node.keywords
                     if kw.arg}
        for kw in node.keywords:
            if kw.arg is None:
                self.ev(kw.value)
        any_taint = any(arg_taints) or any(kw_taints.values())
        fname = resolve_dotted(self.mod, _dotted(node.func)) or ""

        # `.at[traced_idx].set(...)` scatter -- checked before the generic
        # attribute-method handling below.
        if isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Subscript) and \
                isinstance(node.func.value.value, ast.Attribute) and \
                node.func.value.value.attr == "at":
            idx_taint = self.ev(node.func.value.slice)
            recv = self.ev(node.func.value.value.value)
            if idx_taint and self.emit:
                self.engine.report(
                    self.fi, node, "PC-T006",
                    "scatter with a traced index inside traced code "
                    "(XLA CPU scatter is pathologically slow)",
                    hint="restructure as a dense select (jnp.where over "
                         "an arange mask) or move it off the hot path")
            return recv or any_taint

        if isinstance(node.func, ast.Attribute):
            recv_taint = self.ev(node.func.value)
            if node.func.attr in _SYNC_METHODS and recv_taint:
                if self.emit:
                    self.engine.report(
                        self.fi, node, "PC-T001",
                        f".{node.func.attr}() on a traced value forces a "
                        "host sync inside traced code",
                        hint="keep the value on device; reduce with jnp "
                             "and transfer once outside the jit boundary")
                return False
            if node.func.attr == "astype" and recv_taint and \
                    self._is_f64(node.args[0] if node.args else None):
                if self.emit:
                    self._report_f64(node)
                return True

        if fname in _CAST_FUNCS:
            if any_taint:
                if self.emit:
                    self.engine.report(
                        self.fi, node, "PC-T002",
                        f"{fname}() on a traced value concretizes it "
                        "(host round trip; breaks under jit)",
                        hint="keep it as a jnp scalar, or make the "
                             "operand a static (keyword-only) argument")
                return False
            return False
        if fname in _COERCE_FUNCS:
            if any_taint and self.emit:
                self.engine.report(
                    self.fi, node, "PC-T002",
                    f"builtin {fname}() iterates/concretizes a traced "
                    "value on the host",
                    hint=f"use the jnp.{fname} reduction instead")
            return any_taint
        if fname in _STATIC_FUNCS:
            return False
        if fname == "getattr":
            return arg_taints[0] if arg_taints else False

        if fname.startswith("numpy."):
            base = fname.rpartition(".")[2]
            if any_taint:
                if base in _F64_NAMES or fname in _F64_NAMES:
                    if self.emit:
                        self._report_f64(node)
                elif self.emit:
                    self.engine.report(
                        self.fi, node, "PC-T004",
                        f"np.{base}() on a traced value silently syncs "
                        "and computes on host",
                        hint=f"use jnp.{base} (or hoist the numpy work "
                             "outside the traced function)")
                return False
            return False

        if fname.startswith("jax.numpy."):
            base = fname.rpartition(".")[2]
            if base == "float64" and any_taint:
                if self.emit:
                    self._report_f64(node)
                return True
            if base in _SORT_FAMILY and any_taint:
                if self.emit:
                    self.engine.report(
                        self.fi, node, "PC-T006",
                        f"jnp.{base} inside traced code (sort-family ops "
                        "are 10-40x slower than streaming reductions on "
                        "XLA CPU)",
                        hint="stream the statistic through the scan carry "
                             "(see lab.score's fixed-bin quantile)")
            if self._f64_dtype_arg(node):
                if self.emit:
                    self._report_f64(node)
                return True
            return any_taint

        if fname == "jax.lax.sort" and any_taint:
            if self.emit:
                self.engine.report(
                    self.fi, node, "PC-T006",
                    "lax.sort inside traced code", hint="stream instead")
            return True

        if fname == "jax.jit" and self.loop_depth > 0:
            if self.emit:
                self.engine.report(
                    self.fi, node, "PC-T007",
                    "jax.jit constructed inside a loop body builds a fresh "
                    "executable per iteration",
                    hint="hoist the jit out of the loop")

        resolved = self.engine.resolve_callable(self.mod, self.fi, node.func)
        if resolved is not None:
            callee, _ = resolved
            if callee is not self.fi:
                self.engine.propagate_call(callee, node, arg_taints,
                                           kw_taints)
        return any_taint

    def _is_f64(self, node: Optional[ast.AST]) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Constant):
            return node.value in ("float64", "double")
        return (resolve_dotted(self.mod, _dotted(node)) or "") in _F64_NAMES

    def _f64_dtype_arg(self, node: ast.Call) -> bool:
        for kw in node.keywords:
            if kw.arg == "dtype" and self._is_f64(kw.value):
                return True
        return any(self._is_f64(a) for a in node.args[1:])

    def _report_f64(self, node: ast.AST) -> None:
        self.engine.report(
            self.fi, node, "PC-T005",
            "float64 promotion in traced code (the streaming accumulators "
            "are float32 + Kahan compensation by design)",
            hint="stay in float32 and compensate (lab.score.kahan_add), "
                 "or cast outside the traced region")


def analyze_traced(paths: Sequence[str],
                   root: Optional[str] = None) -> List[Finding]:
    """Run TraceLint over ``paths``; returns findings."""
    return TraceLint(paths, root=root).run()
