"""Finding and baseline plumbing shared by both PlaneCheck pass families.

A finding is anchored by ``(rule, file, symbol)``: the file is
repo-relative, the symbol is the enclosing function/method qualname (or
the lock cycle for ``PC-L001``).  The committed baseline matches on
that triple -- not on line numbers -- so unrelated edits to a file do
not invalidate accepted entries, while moving an accepted pattern into
a new function re-surfaces it for review.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Tuple

#: Rule catalog: id -> one-line description (mirrored in the README).
RULES: Dict[str, str] = {
    "PC-T001": "host sync inside traced code (.item/.tolist/"
               ".block_until_ready on a traced value)",
    "PC-T002": "host cast of a traced value (float/int/bool/"
               "min/max/sum/sorted/any/all force concretization)",
    "PC-T003": "Python control flow (if/while/assert/ternary) on a "
               "traced value",
    "PC-T004": "numpy call on a traced value (silent device->host "
               "round trip)",
    "PC-T005": "float64 promotion in traced code (streaming "
               "accumulators are f32-clean by design)",
    "PC-T006": "in-jit sort-family call or scatter with a traced index "
               "(pathological on XLA CPU)",
    "PC-T007": "jax.jit constructed inside a loop body (fresh "
               "executable per iteration)",
    "PC-L001": "lock-order inversion (cycle in the lock-acquisition "
               "graph)",
    "PC-L002": "guarded field mutated without its # guarded-by: lock",
    "PC-L003": "blocking work (compile, device sync, file I/O, join) "
               "while holding a lock",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a concrete site."""

    rule: str
    file: str                  # repo-relative, forward slashes
    line: int
    symbol: str                # enclosing function/method qualname
    message: str
    hint: str = ""

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.file, self.symbol)

    def format(self) -> str:
        text = f"{self.file}:{self.line}: {self.rule} [{self.symbol}] " \
               f"{self.message}"
        if self.hint:
            text += f"\n    hint: {self.hint}"
        return text

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class Baseline:
    """Checked-in accepted findings, each with a justification.

    File format (``PLANECHECK_BASELINE.json``)::

        {"entries": [{"rule": "PC-...", "file": "src/...",
                      "symbol": "qualname",
                      "justification": "one line why this is deliberate"}]}

    An entry without a non-empty justification is itself an error --
    the baseline documents accepted debt, it is not a mute button.
    """

    def __init__(self, entries: Iterable[dict] = ()):
        self.entries: List[dict] = list(entries)
        self._keys = {(e.get("rule", ""), e.get("file", ""),
                       e.get("symbol", "")) for e in self.entries}
        self._hits: set = set()

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls()
        with open(path) as fh:
            doc = json.load(fh)
        return cls(doc.get("entries", []))

    def validate(self) -> List[str]:
        """Malformed-entry errors (missing keys, empty justification)."""
        errors = []
        for e in self.entries:
            missing = [k for k in ("rule", "file", "symbol")
                       if not e.get(k)]
            if missing:
                errors.append(f"baseline entry {e!r} missing {missing}")
            if not str(e.get("justification", "")).strip():
                errors.append(
                    f"baseline entry for {e.get('rule')} at "
                    f"{e.get('file')}:{e.get('symbol')} has no "
                    "justification")
        return errors

    def covers(self, finding: Finding) -> bool:
        if finding.key in self._keys:
            self._hits.add(finding.key)
            return True
        return False

    def stale(self) -> List[dict]:
        """Entries that matched nothing in the last run (drift signal)."""
        return [e for e in self.entries
                if (e.get("rule", ""), e.get("file", ""),
                    e.get("symbol", "")) not in self._hits]

    @staticmethod
    def write(path: str, findings: Iterable[Finding],
              justification: str = "TODO: justify or fix") -> None:
        entries = []
        seen = set()
        for f in findings:
            if f.key in seen:
                continue
            seen.add(f.key)
            entries.append({"rule": f.rule, "file": f.file,
                            "symbol": f.symbol,
                            "justification": justification})
        with open(path, "w") as fh:
            json.dump({"entries": entries}, fh, indent=2)
            fh.write("\n")


def relpath(path: str, root: Optional[str] = None) -> str:
    root = root or os.getcwd()
    try:
        rel = os.path.relpath(os.path.abspath(path), root)
    except ValueError:
        rel = path
    if rel.startswith(".."):
        rel = path
    return rel.replace(os.sep, "/")
