"""LockLint: lock-acquisition graph + ``# guarded-by:`` field discipline.

Model, in three steps, all pure ``ast``:

1. **Discovery** -- per class: lock attributes (``self.X =
   threading.Lock()/RLock()/Condition()``), thread attributes
   (``threading.Thread(...)`` assignments), and guarded fields declared
   with a trailing ``# guarded-by: <lock>`` comment on the assignment
   that introduces them (normally in ``__init__``).  Module-level
   ``_LOCK = threading.Lock()`` globals are tracked too.
2. **Summaries** -- a per-method fixpoint computes, for every method
   and top-level function, the set of locks it may acquire
   (transitively, through resolvable calls) and whether it may block
   (file I/O, ``time.sleep``, ``subprocess``, jit compilation, device
   sync, joining a thread).  ``self.m()`` resolves within the class;
   other ``obj.m()`` calls resolve by method name across all analyzed
   classes, *excluding* container-ish names (``append``, ``get``, ...)
   that would otherwise alias list/dict methods.
3. **Emission** -- a second walk tracks the locks held at each
   statement (``with self._lock:`` / ``.acquire()``), records
   held->acquired edges (including through callee summaries), and
   reports:

   * ``PC-L001`` -- a cycle in the global lock graph (two code paths
     acquiring the same pair of locks in opposite orders); self-loops
     are ignored (RLocks re-enter legally).
   * ``PC-L002`` -- a guarded field written, or mutated via
     ``append``/``pop``/... , with its declared lock not held
     (``__init__`` is exempt: the object is not yet shared).
   * ``PC-L003`` -- blocking work while holding any lock, directly or
     through a callee whose summary blocks.

Escape hatches: ``# locklint: holds <lock>`` on a ``def`` line asserts
a lock the analyzer cannot see (e.g. the caller holds it by contract);
``# planecheck: ignore[RULE]`` on or above a finding line suppresses
it; a ``guarded-by`` naming something that is not a known lock attr
(``join(_thread)``) is documentation-only and not enforced.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding, relpath
from .tracelint import (ModuleInfo, _dotted, _python_files, load_module,
                        resolve_dotted)

_LOCK_CTORS = {"threading.Lock", "threading.RLock", "threading.Condition"}
_THREAD_CTORS = {"threading.Thread"}

#: dotted call targets that can block for unbounded / milliseconds+ time
_BLOCKING_CALLS = {
    "time.sleep": "time.sleep",
    "open": "file I/O (open)",
    "json.dump": "file I/O (json.dump)",
    "json.load": "file I/O (json.load)",
    "numpy.save": "file I/O (np.save)",
    "numpy.load": "file I/O (np.load)",
    "numpy.savez": "file I/O (np.savez)",
    "numpy.savez_compressed": "file I/O (np.savez_compressed)",
    "os.replace": "file I/O (os.replace)",
    "os.fsync": "file I/O (os.fsync)",
    "shutil.rmtree": "file I/O (shutil.rmtree)",
    "shutil.copy": "file I/O (shutil.copy)",
    "shutil.copy2": "file I/O (shutil.copy2)",
    "shutil.copytree": "file I/O (shutil.copytree)",
    "subprocess.run": "subprocess.run",
    "subprocess.Popen": "subprocess.Popen",
    "subprocess.check_output": "subprocess.check_output",
    "pickle.dump": "file I/O (pickle.dump)",
    "pickle.load": "file I/O (pickle.load)",
    "jax.jit": "jit compilation",
    "jax.block_until_ready": "device sync (jax.block_until_ready)",
    "jax.device_get": "device sync (jax.device_get)",
}

#: container/stdlib-ish method names excluded from cross-class resolution
_MUTATORS = {"append", "appendleft", "extend", "insert", "pop", "popleft",
             "remove", "clear", "update", "add", "discard", "setdefault",
             "popitem", "sort", "reverse"}
_GENERIC_METHODS = _MUTATORS | {
    "get", "items", "keys", "values", "copy", "read", "write", "close",
    "acquire", "release", "start", "join", "wait", "notify", "notify_all",
    "put", "index", "count", "split", "strip", "format", "encode",
    "decode", "item", "tolist", "mean", "sum", "astype", "reshape"}

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([\w().\[\]]+)")
_HOLDS_RE = re.compile(r"#\s*locklint:\s*holds\s+([\w.]+)")
_IGNORE_RE = re.compile(r"#\s*planecheck:\s*ignore\[([A-Z0-9-]+)\]")

MethodKey = Tuple[str, Optional[str], str]        # (module, class, method)


@dataclasses.dataclass
class ClassInfo:
    module: ModuleInfo
    name: str
    node: ast.ClassDef
    locks: Set[str] = dataclasses.field(default_factory=set)
    threads: Set[str] = dataclasses.field(default_factory=set)
    guarded: Dict[str, str] = dataclasses.field(default_factory=dict)
    methods: Dict[str, ast.FunctionDef] = dataclasses.field(
        default_factory=dict)

    def lock_id(self, attr: str) -> str:
        return f"{self.name}.{attr}"


@dataclasses.dataclass
class Summary:
    acquires: Set[str] = dataclasses.field(default_factory=set)
    blocks: Optional[str] = None       # reason string, None if non-blocking


class LockLint:
    def __init__(self, paths: Sequence[str], root: Optional[str] = None):
        self.root = root
        self.modules: Dict[str, ModuleInfo] = {}
        for path in _python_files(paths):
            mod = load_module(path)
            if mod is not None:
                self.modules[mod.name] = mod
        self.classes: Dict[Tuple[str, str], ClassInfo] = {}
        self.module_locks: Dict[str, Set[str]] = {}
        self.module_funcs: Dict[Tuple[str, str], ast.FunctionDef] = {}
        self.summaries: Dict[MethodKey, Summary] = {}
        self.method_index: Dict[str, List[MethodKey]] = {}
        self.edges: Dict[Tuple[str, str], Tuple[ModuleInfo, str, int]] = {}
        self.findings: List[Finding] = []
        self._discover()

    # -- discovery ----------------------------------------------------------
    def _discover(self) -> None:
        for mod in self.modules.values():
            self.module_locks[mod.name] = set()
            for stmt in mod.tree.body:
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name) and \
                        self._is_lock_ctor(mod, stmt.value):
                    self.module_locks[mod.name].add(stmt.targets[0].id)
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    self.module_funcs[(mod.name, stmt.name)] = stmt
            for stmt in mod.tree.body:
                if isinstance(stmt, ast.ClassDef):
                    self._discover_class(mod, stmt)
        for (mname, cname), ci in self.classes.items():
            for meth in ci.methods:
                if meth.startswith("__") or meth in _GENERIC_METHODS:
                    continue
                self.method_index.setdefault(meth, []).append(
                    (mname, cname, meth))

    def _is_lock_ctor(self, mod: ModuleInfo, node: ast.AST) -> bool:
        return isinstance(node, ast.Call) and \
            (resolve_dotted(mod, _dotted(node.func)) or "") in _LOCK_CTORS

    def _is_thread_ctor(self, mod: ModuleInfo, node: ast.AST) -> bool:
        return isinstance(node, ast.Call) and \
            (resolve_dotted(mod, _dotted(node.func)) or "") in _THREAD_CTORS

    def _discover_class(self, mod: ModuleInfo, node: ast.ClassDef) -> None:
        ci = ClassInfo(module=mod, name=node.name, node=node)
        self.classes[(mod.name, node.name)] = ci
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ci.methods[item.name] = item
                for sub in ast.walk(item):
                    tgt = None
                    if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                        tgt = sub.targets[0]
                    elif isinstance(sub, ast.AnnAssign):
                        tgt = sub.target
                    if not (isinstance(tgt, ast.Attribute) and
                            isinstance(tgt.value, ast.Name) and
                            tgt.value.id == "self"):
                        continue
                    value = getattr(sub, "value", None)
                    if value is not None and self._is_lock_ctor(mod, value):
                        ci.locks.add(tgt.attr)
                    if value is not None and self._is_thread_ctor(mod,
                                                                  value):
                        ci.threads.add(tgt.attr)
                    ann = getattr(sub, "annotation", None)
                    if ann is not None and "Thread" in ast.dump(ann):
                        ci.threads.add(tgt.attr)
                    end = getattr(sub, "end_lineno", sub.lineno) or \
                        sub.lineno
                    for ln in range(sub.lineno, min(end, len(mod.lines))
                                    + 1):
                        m = _GUARDED_RE.search(mod.lines[ln - 1])
                        if m:
                            ci.guarded[tgt.attr] = m.group(1)
                            break

    # -- summaries ----------------------------------------------------------
    def compute_summaries(self) -> None:
        keys: List[MethodKey] = []
        for (mname, cname), ci in self.classes.items():
            keys.extend((mname, cname, meth) for meth in ci.methods)
        keys.extend((mname, None, fname)
                    for (mname, fname) in self.module_funcs)
        for k in keys:
            self.summaries[k] = Summary()
        for _ in range(10):
            changed = False
            for k in keys:
                walker = _MethodWalker(self, k, emit=False)
                walker.walk()
                summ = self.summaries[k]
                if not walker.acquired <= summ.acquires:
                    summ.acquires |= walker.acquired
                    changed = True
                if walker.blocks and summ.blocks is None:
                    summ.blocks = walker.blocks
                    changed = True
            if not changed:
                break

    # -- driver -------------------------------------------------------------
    def run(self) -> List[Finding]:
        self.compute_summaries()
        for k in self.summaries:
            _MethodWalker(self, k, emit=True).walk()
        self._report_cycles()
        return self.findings

    def _report_cycles(self) -> None:
        graph: Dict[str, Set[str]] = {}
        for (a, b) in self.edges:
            if a != b:
                graph.setdefault(a, set()).add(b)
        seen_cycles: Set[frozenset] = set()
        for start in sorted(graph):
            cyc = self._find_cycle(graph, start)
            if cyc is None or frozenset(cyc) in seen_cycles:
                continue
            seen_cycles.add(frozenset(cyc))
            pairs = list(zip(cyc, cyc[1:] + [cyc[0]]))
            mod, sym, line = self.edges.get(
                pairs[0], next(iter(self.edges.values())))
            chain = " -> ".join(cyc + [cyc[0]])
            sites = "; ".join(
                f"{a}->{b} at {self.edges[(a, b)][1]}"
                for a, b in pairs if (a, b) in self.edges)
            self._report(mod, sym, line, "PC-L001", chain,
                         f"lock-order inversion: {chain} ({sites})",
                         hint="pick one global order (tick -> plane -> "
                              "controller -> history) and acquire in "
                              "that order everywhere")

    def _find_cycle(self, graph: Dict[str, Set[str]],
                    start: str) -> Optional[List[str]]:
        path: List[str] = []
        on_path: Set[str] = set()
        visited: Set[str] = set()

        def dfs(n: str) -> Optional[List[str]]:
            path.append(n)
            on_path.add(n)
            for nxt in sorted(graph.get(n, ())):
                if nxt in on_path:
                    return path[path.index(nxt):]
                if nxt not in visited:
                    got = dfs(nxt)
                    if got is not None:
                        return got
            on_path.discard(n)
            visited.add(n)
            path.pop()
            return None

        return dfs(start)

    def _report(self, mod: ModuleInfo, symbol: str, line: int, rule: str,
                symbol_override: Optional[str], message: str,
                hint: str = "") -> None:
        if mod.line_has_ignore(line, rule):
            return
        f = Finding(
            rule=rule, file=relpath(mod.path, self.root), line=line,
            symbol=symbol_override or symbol, message=message, hint=hint)
        if not any(g.key == f.key and g.line == f.line
                   for g in self.findings):
            self.findings.append(f)


class _MethodWalker:
    def __init__(self, engine: LockLint, key: MethodKey, emit: bool):
        self.engine = engine
        self.key = key
        mname, cname, meth = key
        self.mod = engine.modules[mname]
        self.ci = engine.classes.get((mname, cname)) if cname else None
        self.node = (self.ci.methods[meth] if self.ci
                     else engine.module_funcs[(mname, meth)])
        self.symbol = f"{cname}.{meth}" if cname else meth
        self.emit = emit
        self.is_init = meth == "__init__"
        self.acquired: Set[str] = set()
        self.blocks: Optional[str] = None
        self.local_threads: Set[str] = set()
        self.held: List[str] = list(self._pragma_holds())

    def _pragma_holds(self) -> List[str]:
        line = self.mod.lines[self.node.lineno - 1] \
            if self.node.lineno <= len(self.mod.lines) else ""
        m = _HOLDS_RE.search(line)
        if not m:
            return []
        name = m.group(1)
        if "." in name:
            return [name]
        if self.ci and name in self.ci.locks:
            return [self.ci.lock_id(name)]
        return [name]

    # -- lock identification ------------------------------------------------
    def _lock_of(self, expr: ast.AST) -> Optional[str]:
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and \
                expr.value.id == "self" and self.ci and \
                expr.attr in self.ci.locks:
            return self.ci.lock_id(expr.attr)
        if isinstance(expr, ast.Name) and \
                expr.id in self.engine.module_locks.get(self.mod.name, ()):
            return f"{self.mod.name}.{expr.id}"
        return None

    def _thread_like(self, expr: ast.AST) -> bool:
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and expr.value.id == "self":
            if self.ci and expr.attr in self.ci.threads:
                return True
            return "thread" in expr.attr.lower()
        if isinstance(expr, ast.Name):
            return expr.id in self.local_threads or \
                "thread" in expr.id.lower()
        return False

    # -- walking ------------------------------------------------------------
    def walk(self) -> None:
        self.block(self.node.body)

    def block(self, stmts) -> None:
        for s in stmts:
            self.stmt(s)

    def _acquire(self, lock: str, node: ast.AST) -> int:
        for h in self.held:
            if h != lock:
                self.engine.edges.setdefault(
                    (h, lock), (self.mod, self.symbol, node.lineno))
        self.acquired.add(lock)
        self.held.append(lock)
        return 1

    def stmt(self, s: ast.stmt) -> None:
        if isinstance(s, ast.With):
            pushed = 0
            for item in s.items:
                lock = self._lock_of(item.context_expr)
                if lock is not None:
                    pushed += self._acquire(lock, s)
                else:
                    self.expr(item.context_expr)
            self.block(s.body)
            for _ in range(pushed):
                self.held.pop()
            return
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                      # nested defs run later, not here
        if isinstance(s, ast.Assign):
            self.expr(s.value)
            if len(s.targets) == 1 and isinstance(s.targets[0], ast.Name) \
                    and self.engine._is_thread_ctor(self.mod, s.value):
                self.local_threads.add(s.targets[0].id)
            for t in s.targets:
                self.store(t, s)
            return
        if isinstance(s, ast.AugAssign):
            self.expr(s.value)
            self.store(s.target, s)
            return
        if isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self.expr(s.value)
                self.store(s.target, s)
            return
        if isinstance(s, ast.Expr):
            self.expr(s.value)
            return
        if isinstance(s, (ast.If, ast.While)):
            self.expr(s.test)
            self.block(s.body)
            self.block(s.orelse)
            return
        if isinstance(s, ast.For):
            self.expr(s.iter)
            self.block(s.body)
            self.block(s.orelse)
            return
        if isinstance(s, ast.Try):
            self.block(s.body)
            for h in s.handlers:
                self.block(h.body)
            self.block(s.orelse)
            self.block(s.finalbody)
            return
        if isinstance(s, ast.Return) and s.value is not None:
            self.expr(s.value)
            return
        if isinstance(s, ast.Raise) and s.exc is not None:
            self.expr(s.exc)
            return
        if isinstance(s, ast.Assert):
            self.expr(s.test)
            return

    def store(self, target: ast.AST, stmt: ast.stmt) -> None:
        """Check a write target against guarded-by declarations."""
        if isinstance(target, (ast.Tuple, ast.List)):
            for t in target.elts:
                self.store(t, stmt)
            return
        attr = None
        if isinstance(target, ast.Attribute) and \
                isinstance(target.value, ast.Name) and \
                target.value.id == "self":
            attr = target.attr
        elif isinstance(target, ast.Subscript):
            base = target.value
            if isinstance(base, ast.Attribute) and \
                    isinstance(base.value, ast.Name) and \
                    base.value.id == "self":
                attr = base.attr
        if attr is not None:
            self._check_guard(attr, stmt)

    def _check_guard(self, attr: str, node: ast.AST) -> None:
        if not self.emit or self.is_init or self.ci is None:
            return
        guard = self.ci.guarded.get(attr)
        if guard is None or guard not in self.ci.locks:
            return                      # unknown guard = documentation only
        if self.ci.lock_id(guard) in self.held:
            return
        self.engine._report(
            self.mod, self.symbol, getattr(node, "lineno", 1), "PC-L002",
            None,
            f"self.{attr} is declared `# guarded-by: {guard}` but is "
            f"mutated without {self.ci.name}.{guard} held",
            hint=f"wrap the mutation in `with self.{guard}:` (or move it "
                 "into a method that already holds it)")

    def _blocking_reason(self, call: ast.Call) -> Optional[str]:
        fname = resolve_dotted(self.mod, _dotted(call.func)) or ""
        if fname in _BLOCKING_CALLS:
            return _BLOCKING_CALLS[fname]
        if isinstance(call.func, ast.Attribute):
            meth = call.func.attr
            recv = call.func.value
            if meth == "block_until_ready":
                return "device sync (.block_until_ready)"
            if meth == "join" and self._thread_like(recv):
                return "thread join"
            if meth in ("result", "get") and "future" in ast.dump(
                    recv).lower():
                return "future wait"
        return None

    def expr(self, node: Optional[ast.AST]) -> None:
        if node is None:
            return
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._call(sub)

    def _call(self, call: ast.Call) -> None:
        # in-place mutation of a guarded container: self.F.append(...)
        if isinstance(call.func, ast.Attribute) and \
                call.func.attr in _MUTATORS:
            recv = call.func.value
            if isinstance(recv, ast.Attribute) and \
                    isinstance(recv.value, ast.Name) and \
                    recv.value.id == "self":
                self._check_guard(recv.attr, call)
        reason = self._blocking_reason(call)
        if reason is not None:
            if self.blocks is None:
                self.blocks = reason
            if self.held and self.emit:
                self.engine._report(
                    self.mod, self.symbol, call.lineno, "PC-L003", None,
                    f"blocking work ({reason}) while holding "
                    f"{', '.join(self.held)}",
                    hint="prepare outside the lock, commit inside "
                         "(the prewarm-outside/swap-inside discipline)")
            return

        # explicit .acquire() -- held for the remainder of the method
        if isinstance(call.func, ast.Attribute) and \
                call.func.attr == "acquire":
            lock = self._lock_of(call.func.value)
            if lock is not None:
                self._acquire(lock, call)
                return

        for summ in self._resolve(call):
            if summ.blocks is not None:
                if self.blocks is None:
                    self.blocks = summ.blocks
                if self.held and self.emit:
                    self.engine._report(
                        self.mod, self.symbol, call.lineno, "PC-L003",
                        None,
                        f"call may block ({summ.blocks}) while holding "
                        f"{', '.join(self.held)}",
                        hint="hoist the blocking call out of the locked "
                             "region")
            for lock in summ.acquires:
                for h in self.held:
                    if h != lock:
                        self.engine.edges.setdefault(
                            (h, lock), (self.mod, self.symbol,
                                        call.lineno))

    def _resolve(self, call: ast.Call) -> List[Summary]:
        """Summaries of the callee(s), if resolvable."""
        func = call.func
        out: List[Summary] = []
        if isinstance(func, ast.Name):
            key = (self.mod.name, None, func.id)
            if key in self.engine.summaries:
                out.append(self.engine.summaries[key])
            else:
                target = resolve_dotted(self.mod, func.id) or ""
                mname, _, fname = target.rpartition(".")
                key = (mname, None, fname)
                if key in self.engine.summaries:
                    out.append(self.engine.summaries[key])
            return out
        if not isinstance(func, ast.Attribute):
            return out
        meth = func.attr
        recv = func.value
        # self.m() -- precise, in-class
        if isinstance(recv, ast.Name) and recv.id == "self" and self.ci:
            key = (self.mod.name, self.ci.name, meth)
            if key in self.engine.summaries:
                out.append(self.engine.summaries[key])
            return out
        # lock-object methods (cv.wait / lock.release) are not user code
        if self._lock_of(recv) is not None:
            return out
        # obj.m() -- by-name union across analyzed classes
        for key in self.engine.method_index.get(meth, ()):
            out.append(self.engine.summaries[key])
        return out


def analyze_locks(paths: Sequence[str],
                  root: Optional[str] = None) -> List[Finding]:
    """Run LockLint over ``paths``; returns findings."""
    return LockLint(paths, root=root).run()
