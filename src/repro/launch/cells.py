"""Benchmark-cell construction: (arch x shape x mesh) -> lowerable fn.

One entry point, :func:`build_cell`, returns everything the dry-run
needs: the step function, ShapeDtypeStruct inputs, and NamedSharding
trees.  No device arrays are ever created for full-size configs.

Cell kinds (configs/base.py):

* train   -> the *real* train step (loss + grad + clip + AdamW), with
             per-arch microbatching from :data:`DRYRUN_SETTINGS`,
* prefill -> batched forward (logits), the serving prefill phase,
* decode  -> one-token serve step over the full-length KV cache.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import get_config, get_shape
from ..configs.base import ArchConfig, InputShape
from ..models import decode as D
from ..models.params import Axes, axes_for, param_shapes, param_specs
from ..models.transformer import Model
from ..optim.adamw import AdamWState, opt_state_specs
from ..train.step import TrainStepConfig, TrainState, build_train_step


@dataclass(frozen=True)
class CellSettings:
    microbatches: int = 1
    remat: str = "full"
    attn_impl: str = "chunked"
    attn_chunk: int = 1024
    params_dtype: str = "bfloat16"
    seq_parallel: bool = False


# Per-arch dry-run knobs for the train_4k cell (1M tokens/step).  The
# microbatch count is the activation-memory lever: chosen so layer-
# boundary activations fit ~16 GB/chip HBM alongside params + moments.
DRYRUN_SETTINGS: Dict[Tuple[str, str], CellSettings] = {
    ("mistral-large-123b", "train_4k"): CellSettings(microbatches=16),
    ("dbrx-132b", "train_4k"): CellSettings(microbatches=2),
    ("llama-3.2-vision-11b", "train_4k"): CellSettings(microbatches=4),
    ("whisper-large-v3", "train_4k"): CellSettings(microbatches=4),
    ("qwen2-moe-a2.7b", "train_4k"): CellSettings(microbatches=2),
    ("hymba-1.5b", "train_4k"): CellSettings(microbatches=2),
}


def cell_settings(arch: str, shape: str) -> CellSettings:
    return DRYRUN_SETTINGS.get((arch, shape), CellSettings())


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype),
                                sharding=sharding)


def _shard(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_inputs(cfg: ArchConfig, shape: InputShape, axes: Axes, mesh,
                 *, with_labels: bool):
    """ShapeDtypeStructs (+shardings) for a train/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    bspec = axes.batch_spec(None)
    tok = sds((b, s), "int32", NamedSharding(mesh, bspec))
    batch = {"tokens": tok}
    if with_labels:
        batch["labels"] = tok
    if cfg.family == "audio":
        batch["frames"] = sds((b, s, cfg.d_model), "bfloat16",
                              NamedSharding(mesh, axes.batch_spec(None, None)))
    if cfg.family == "vlm":
        batch["images"] = sds((b, cfg.vision_tokens, cfg.d_model),
                              "bfloat16",
                              NamedSharding(mesh, axes.batch_spec(None, None)))
    return batch


def build_cell(arch: str, shape_name: str, mesh,
               settings: Optional[CellSettings] = None):
    """-> (fn, example_inputs (tuple of SDS trees), description dict)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not cfg.supports_shape(shape):
        raise ValueError(
            f"{arch} skips {shape_name} (full attention at 500k; "
            "DESIGN.md §5)")
    st = settings or cell_settings(arch, shape_name)
    axes = axes_for(mesh)
    model = Model(cfg, axes=axes, remat=st.remat, attn_impl=st.attn_impl,
                  attn_chunk=st.attn_chunk)
    model.seq_parallel = st.seq_parallel
    pdtype = jnp.dtype(st.params_dtype)
    pspecs = model.specs()
    pshapes = param_shapes(model.schema(), pdtype)
    pshard = _shard(mesh, pspecs)
    pshapes = jax.tree.map(
        lambda x, sh: sds(x.shape, x.dtype, sh), pshapes, pshard)

    desc = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "n_params": cfg.n_params(),
            "n_active_params": cfg.n_active_params(),
            # decode steps process one token per sequence
            "tokens": (shape.global_batch if shape.kind == "decode"
                       else shape.tokens),
            "settings": st.__dict__,
            "sharding_report": cfg.sharding_report(
                *_mesh_dm(mesh))}

    if shape.kind == "train":
        tcfg = TrainStepConfig(microbatches=st.microbatches)
        step = build_train_step(model, tcfg)
        mu = jax.tree.map(lambda x: sds(x.shape, "float32", x.sharding),
                          pshapes)
        state = TrainState(
            adam=AdamWState(step=sds((), "int32",
                                     NamedSharding(mesh, P())),
                            mu=mu, nu=mu),
            compression=None)
        batch = batch_inputs(cfg, shape, axes, mesh, with_labels=True)
        return step, (pshapes, state, batch), desc

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            logits, _ = model.forward(params, batch)
            return logits
        batch = batch_inputs(cfg, shape, axes, mesh, with_labels=False)
        return prefill_fn, (pshapes, batch), desc

    # decode
    sschema = D.state_schema(model, shape.global_batch, shape.seq_len)
    sspecs = D.param_specs(sschema)
    sshapes = D.param_shapes(sschema, jnp.bfloat16)
    sshard = _shard(mesh, sspecs)
    sshapes = jax.tree.map(lambda x, sh: sds(x.shape, x.dtype, sh),
                           sshapes, sshard)
    tok = sds((shape.global_batch, 1), "int32",
              NamedSharding(mesh, axes.batch_spec(None)
                            if shape.global_batch > 1 else P(None, None)))

    # weight-stationary decode: replicate the one-token activations so
    # the 256-way-sharded weights are never gathered (§Perf cell C2)
    model._replicate_acts = True
    tok = sds((shape.global_batch, 1), "int32",
              NamedSharding(mesh, P(None, None)))

    def serve_fn(params, state, tokens):
        # benchmark decode: synchronized positions -> copy-free cache
        # update; donate the state so caches update in place
        return D.decode_step(model, params, state, tokens,
                             uniform_pos=True)

    serve_fn.donate_argnums = (1,)
    return serve_fn, (pshapes, sshapes, tok), desc


def _mesh_dm(mesh) -> Tuple[int, int]:
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    return names.get("data", 1), names.get("model", 1)


# ---------------------------------------------------------------------------
# Per-cell FleetPlane deployment
# ---------------------------------------------------------------------------

# Serving cells are latency-critical (decode above prefill); training
# tolerates throughput dips, so it arbitrates at the bottom.
DEFAULT_CELL_PRIORITY: Dict[str, int] = {"decode": 2, "prefill": 1,
                                         "train": 0}


def cell_tenant(arch: str, shape_name: str, *, plane,
                weight: Optional[float] = None,
                priority: Optional[int] = None,
                floor_gib: float = 0.0):
    """Wrap one benchmark cell's memory plane as a fleet tenant.

    The nestable-spec refactor's deployment hook: a cell (arch x shape)
    that already declares a host-memory ``PlaneSpec`` for its dataset /
    KV caches becomes a :class:`~repro.fleet.specs.TenantSpec` that a
    :class:`~repro.fleet.specs.FleetSpec` can arbitrate beside other
    cells sharing the host.  Defaults derive from the cell itself:
    ``weight`` scales with active parameters (bigger models keep more
    working state per node), ``priority`` from the cell kind
    (:data:`DEFAULT_CELL_PRIORITY` -- serving above training).
    """
    from ..fleet.specs import TenantSpec
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if weight is None:
        weight = max(cfg.n_active_params() / 1e9, 0.25)
    if priority is None:
        priority = DEFAULT_CELL_PRIORITY.get(shape.kind, 0)
    return TenantSpec(name=f"{arch}:{shape_name}", plane=plane,
                      weight=float(weight), priority=int(priority),
                      floor_gib=float(floor_gib))
