"""Serving entry point: continuous batching over a DynIMS-managed pool.

    python -m repro.launch.serve --arch llama3.2-1b-smoke --requests 16

Runs the engine against synthetic prompts, printing throughput and pool
behaviour.  ``--burst`` simulates a host/device memory burst mid-run by
shrinking the KV pool through its controller (the paper's Fig. 7
scenario on the serving path) and reports preemption/recovery.
``--retune`` closes the ReplayLoop on the serving path: the plane
records its own KV-pool telemetry during the first wave of requests,
``retune_online`` re-tunes the pool gains on the captured workload and
hot-swaps the winner into the live plane, and a second wave serves
under the new parameter epoch.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--burst", action="store_true")
    ap.add_argument("--retune", action="store_true",
                    help="capture the KV-pool workload, re-tune the pool "
                         "gains on it online, hot-swap, serve a second wave")
    ap.add_argument("--retune-budget", type=int, default=16)
    ap.add_argument("--retune-restarts", type=int, default=2,
                    help="supervised retune: restart a crashed tuning "
                         "round up to N times with backoff")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs import get_config
    from ..configs.dynims import hbm_pool_params
    from ..core.plane import MemoryPlane, PlaneSpec
    from ..models import Model
    from ..serving import ServingConfig, ServingEngine

    cfg = get_config(args.arch)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.key(args.seed))
    plane = MemoryPlane(PlaneSpec(params=hbm_pool_params(),
                                  record=2048 if args.retune else 0))
    engine = ServingEngine(model, params,
                           ServingConfig(max_batch=args.max_batch,
                                         max_len=args.max_len),
                           plane=plane)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                      max_new_tokens=args.max_new)

    t0 = time.time()
    if args.burst:
        for _ in range(10):
            engine.step()
        print("-- memory burst: shrinking KV pool to 25% --")
        engine.pool.set_capacity(engine.pool.capacity() * 0.25)
        print("   (preempted sequences requeue; with no sustained device "
              "pressure the plane re-grants capacity on the next tick)")
        for _ in range(5):
            engine.step()
    finished = engine.run_until_drained()
    dt = time.time() - t0
    stats = engine.stats()
    toks = sum(len(r.output) for r in finished.values())
    print(f"served {len(finished)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    print("engine:", stats)

    if args.retune:
        from ..lab.tune import retune_online
        print("-- ReplayLoop: re-tuning pool gains on the captured "
              "KV workload --")
        handle = retune_online(plane, name="kv-pool-replay",
                               budget=args.retune_budget, block=False,
                               restarts=args.retune_restarts)
        result = handle.result()
        print("  ", result.summary())
        if handle.restarts:
            print(f"   retune supervisor: {handle.attempts} attempts, "
                  f"{handle.restarts} restarts")
        p = plane.params
        print(f"   live params now: r0={p.r0:.4f} lam={p.lam:.4f} "
              f"lam_grant={p.lam_grant} (epoch {plane.epoch})")
        print("  ", plane.health().summary())
        for _ in range(max(args.requests // 2, 1)):
            engine.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                          max_new_tokens=args.max_new)
        wave2 = engine.run_until_drained()
        print(f"   second wave under epoch {plane.epoch}: served "
              f"{len(wave2)} requests")


if __name__ == "__main__":
    main()
