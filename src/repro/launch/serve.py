"""Serving entry point: continuous batching over a DynIMS-managed pool.

    python -m repro.launch.serve --arch llama3.2-1b-smoke --requests 16

Runs the engine against synthetic prompts, printing throughput and pool
behaviour.  ``--burst`` simulates a host/device memory burst mid-run by
shrinking the KV pool through its controller (the paper's Fig. 7
scenario on the serving path) and reports preemption/recovery.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--burst", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs import get_config
    from ..configs.dynims import hbm_pool_params
    from ..core.plane import MemoryPlane, PlaneSpec
    from ..models import Model
    from ..serving import ServingConfig, ServingEngine

    cfg = get_config(args.arch)
    model = Model(cfg, remat="none")
    params = model.init(jax.random.key(args.seed))
    plane = MemoryPlane(PlaneSpec(params=hbm_pool_params()))
    engine = ServingEngine(model, params,
                           ServingConfig(max_batch=args.max_batch,
                                         max_len=args.max_len),
                           plane=plane)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                      max_new_tokens=args.max_new)

    t0 = time.time()
    if args.burst:
        for _ in range(10):
            engine.step()
        print("-- memory burst: shrinking KV pool to 25% --")
        engine.pool.set_capacity(engine.pool.capacity() * 0.25)
        print("   (preempted sequences requeue; with no sustained device "
              "pressure the plane re-grants capacity on the next tick)")
        for _ in range(5):
            engine.step()
    finished = engine.run_until_drained()
    dt = time.time() - t0
    stats = engine.stats()
    toks = sum(len(r.output) for r in finished.values())
    print(f"served {len(finished)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    print("engine:", stats)


if __name__ == "__main__":
    main()
