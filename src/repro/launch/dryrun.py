import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape) cell, on BOTH production meshes
(16x16 single-pod and 2x16x16 multi-pod), this driver:

    lowered  = jax.jit(step_fn).lower(*input_specs)   # SDS, no arrays
    compiled = lowered.compile()
    print(compiled.memory_analysis())                 # proves it fits
    print(compiled.cost_analysis())                   # -> §Roofline

and writes one JSON artifact per cell under results/dryrun/.  Failures
(sharding mismatch, OOM at compile, unsupported collective) are bugs.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
    python -m repro.launch.dryrun --all --jobs 4      # process pool
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             settings_override: dict = None, tag: str = "") -> dict:
    import jax

    from ..configs import get_config, get_shape
    from ..launch.cells import CellSettings, build_cell, cell_settings
    from ..launch.mesh import activate_mesh, describe, make_production_mesh
    from ..roofline.analysis import analyze_compiled

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with activate_mesh(mesh):
        st = cell_settings(arch, shape)
        if settings_override:
            import dataclasses
            st = dataclasses.replace(st, **settings_override)
        fn, inputs, desc = build_cell(arch, shape, mesh, settings=st)
        desc["mesh"] = describe(mesh)
        desc["multi_pod"] = multi_pod

        donate = getattr(fn, "donate_argnums", ())
        lowered = jax.jit(fn, donate_argnums=donate).lower(*inputs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    n_chips = int(mesh.devices.size)
    hlo_text = compiled.as_text()
    result = analyze_compiled(compiled, desc, n_chips, hlo_text=hlo_text)
    result["timing"] = {"lower_s": round(t_lower, 1),
                        "compile_s": round(t_compile, 1)}

    mesh_tag = "pod2" if multi_pod else "pod1"
    name = f"{arch}__{shape}__{mesh_tag}{tag}.json"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name)
    with open(path + ".tmp", "w") as fh:
        json.dump(result, fh, indent=1, default=str)
    os.replace(path + ".tmp", path)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--settings", default="",
                    help='JSON overrides, e.g. {"microbatches":8}')
    args = ap.parse_args()

    from ..configs import cells as all_cells

    if args.all:
        targets = all_cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        targets = [(args.arch, args.shape)]

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)
    if args.multi_pod:
        meshes = [True]

    overrides = json.loads(args.settings) if args.settings else None
    failures = []
    for arch, shape in targets:
        for mp in meshes:
            tag = "pod2" if mp else "pod1"
            out = os.path.join(args.out,
                               f"{arch}__{shape}__{tag}.json")
            if args.skip_existing and os.path.exists(out):
                print(f"[skip] {arch} x {shape} x {tag}")
                continue
            print(f"[dryrun] {arch} x {shape} x {tag} ...", flush=True)
            try:
                r = run_cell(arch, shape, mp, args.out,
                             settings_override=overrides)
                t = r["roofline"]
                print(f"  ok ({r['timing']['compile_s']}s compile) "
                      f"compute={t['compute_s']:.4f}s "
                      f"memory={t['memory_s']:.4f}s "
                      f"collective={t['collective_s']:.4f}s "
                      f"dominant={t['dominant']}", flush=True)
                ma = r.get("memory_analysis", {})
                if "temp_size_in_bytes" in ma:
                    per = (ma.get("argument_size_in_bytes", 0)
                           + ma.get("temp_size_in_bytes", 0))
                    print(f"  memory/device: args+temp = {per/2**30:.2f} GiB")
            except Exception as e:
                failures.append((arch, shape, tag, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nall dry-run cells compiled")
    return 0


if __name__ == "__main__":
    sys.exit(main())
