"""Distributed training entry point.

    python -m repro.launch.train --arch llama3.2-1b-smoke --steps 100

Small configs run for real on whatever devices exist (CPU here); full
configs lower for the production mesh (use launch/dryrun.py for that).
Wires: config -> Model -> DataPipeline (DynIMS-managed host cache) ->
pjit'd train step -> Trainer (checkpoint/restart, heartbeats,
stragglers).

Multi-pod notes baked in here rather than hidden in a doc:

* gradient all-reduce over ``pod`` overlaps the backward pass via XLA's
  latency-hiding scheduler; on real TPU set
  ``--xla_tpu_enable_latency_hiding_scheduler=true`` (XLA_FLAGS) --
  recorded in EXPERIMENTS.md §Perf as the collective-overlap knob.
* ``--compress`` enables int8 error-feedback gradient compression for
  the pod-crossing reduction (optim/compress.py).
"""

from __future__ import annotations

import argparse
import os
import tempfile

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--data-dir", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs import get_config
    from ..configs.dynims import host_cache_params
    from ..core import GiB
    from ..core.plane import MemoryPlane, PlaneSpec
    from ..data import DataPipeline, PipelineConfig, ShardStore, write_corpus
    from ..models import Model
    from ..train import Trainer, TrainerConfig, TrainStepConfig

    cfg = get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(args.seed))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,}")

    data_dir = args.data_dir or os.path.join(tempfile.gettempdir(),
                                             f"repro-corpus-{cfg.name}")
    if not os.path.exists(os.path.join(data_dir, "manifest.json")):
        write_corpus(data_dir, n_shards=32,
                     tokens_per_shard=max(args.seq_len * 16, 4096),
                     vocab_size=cfg.vocab_size, seed=args.seed)

    plane = MemoryPlane(PlaneSpec(params=host_cache_params(64 * GiB)))
    pipe = DataPipeline(
        ShardStore(data_dir),
        PipelineConfig(batch_size=args.batch_size, seq_len=args.seq_len,
                       seed=args.seed, cache_bytes=64 * 2**20),
        plane=plane)

    ckpt_dir = args.checkpoint_dir or os.path.join(
        tempfile.gettempdir(), f"repro-ckpt-{cfg.name}")
    trainer = Trainer(
        model, pipe,
        TrainStepConfig(microbatches=args.microbatches, peak_lr=args.lr,
                        warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps, compress=args.compress),
        TrainerConfig(steps=args.steps, checkpoint_dir=ckpt_dir,
                      checkpoint_every=args.checkpoint_every),
        plane=plane)

    if args.resume:
        params, _ = trainer.resume(params)
    else:
        params, _ = trainer.fit(params)
    for row in trainer.metrics_log:
        print({k: round(v, 4) if isinstance(v, float) else v
               for k, v in row.items()})
    pipe.close()


if __name__ == "__main__":
    main()
