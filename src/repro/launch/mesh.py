"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count locks on first jax init, and the
dry-run must set XLA_FLAGS before that happens).

Mesh semantics (DESIGN.md §4):

* ``pod``   -- pure data parallelism across pods; gradient all-reduce is
  the only collective crossing it (optionally int8-compressed).
* ``data``  -- FSDP + batch sharding within a pod.
* ``model`` -- tensor/expert parallelism (heads, d_ff, vocab, experts).
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple

import jax


def activate_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh.

    ``jax.set_mesh`` only exists on newer jax (where it both sets the
    mesh and returns a context manager restoring the previous one); on
    older releases (<= 0.4.x) entering the ``Mesh`` context manager
    provides the same ambient-mesh semantics (bare ``PartitionSpec``
    sharding constraints resolve against it) for the duration of the
    block.  Either way the mesh is only ambient inside the ``with``.
    """
    if hasattr(jax, "set_mesh"):
        ctx = jax.set_mesh(mesh)
        return ctx if ctx is not None else contextlib.nullcontext(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (elastic re-mesh path, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def describe(mesh) -> dict:
    return {
        "shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
        "axis_names": list(mesh.axis_names),
    }
