"""Model zoo: every assigned architecture family in pure JAX."""

from .params import (Axes, ParamDef, Schema, axes_for, count_params,
                     init_params, param_shapes, param_specs, stack_schema)
from .transformer import Model

__all__ = ["Axes", "Model", "ParamDef", "Schema", "axes_for",
           "count_params", "init_params", "param_shapes", "param_specs",
           "stack_schema"]
