"""Common layers: norms, rotary embeddings, MLPs, token embedding/readout.

Functional style throughout: ``<layer>_schema(cfg, axes)`` declares the
parameters (see :mod:`repro.models.params`), ``<layer>(params, x, ...)``
applies them.  All matmuls accumulate in float32 via
``preferred_element_type`` so bf16 runs are numerically sane on the MXU.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .params import Axes, ParamDef, Schema

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_schema(cfg: ArchConfig, name: str = "scale") -> Schema:
    d = {name: ParamDef((cfg.d_model,), P(None), init="ones")}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDef((cfg.d_model,), P(None), init="zeros")
    return d


def apply_norm(params: Schema, x: jax.Array, cfg: ArchConfig,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * params["scale"].astype(F32) + params["bias"].astype(F32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(F32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(F32) * freqs      # (..., S, hd/2)
    angles = angles[..., None, :]                             # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_schema(cfg: ArchConfig, axes: Axes, d_ff: Optional[int] = None) -> Schema:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    tp = axes.tp if f % _size_hint(axes.tp) == 0 else None
    sch: Schema = {
        "wi": ParamDef((d, f), P(axes.fsdp, tp)),
        "wo": ParamDef((f, d), P(tp, axes.fsdp)),
    }
    if cfg.mlp_gated:
        sch["wg"] = ParamDef((d, f), P(axes.fsdp, tp))
    return sch


def _size_hint(axis) -> int:
    # Divisibility is finally decided by the mesh at lowering time; the
    # schema only needs "shardable at all".  16 is the production TP size.
    return 16 if axis else 1


def apply_mlp(params: Schema, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = jnp.einsum("...d,df->...f", x, params["wi"],
                   preferred_element_type=F32)
    if cfg.mlp_gated:
        g = jnp.einsum("...d,df->...f", x, params["wg"],
                       preferred_element_type=F32)
        h = act(g) * h
    else:
        h = act(h)
    out = jnp.einsum("...f,fd->...d", h.astype(x.dtype), params["wo"],
                     preferred_element_type=F32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding + logits readout
# ---------------------------------------------------------------------------

def embedding_schema(cfg: ArchConfig, axes: Axes) -> Schema:
    v, d = cfg.padded_vocab, cfg.d_model
    sch: Schema = {
        "tokens": ParamDef((v, d), P(axes.tp, axes.fsdp), init="small"),
    }
    if not cfg.tie_embeddings:
        sch["unembed"] = ParamDef((d, v), P(axes.fsdp, axes.tp))
    return sch


def embed_tokens(params: Schema, tokens: jax.Array, cfg: ArchConfig,
                 dtype=jnp.bfloat16) -> jax.Array:
    out = jnp.take(params["tokens"], tokens, axis=0)
    if cfg.name.startswith("gemma"):
        out = out * jnp.asarray(cfg.d_model ** 0.5, out.dtype)
    return out.astype(dtype)


def unembed(params: Schema, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["tokens"].T
    else:
        w = params["unembed"]
    logits = jnp.einsum("...d,dv->...v", x, w, preferred_element_type=F32)
    if cfg.attn_logit_softcap:   # gemma-style final softcap reuse
        pass
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  z_loss: float = 1e-4) -> jax.Array:
    """Token-mean CE with an optional z-loss regularizer (MaxText-style).

    Sharded-vocab safe: the gold logit is extracted with a one-hot
    reduction (iota-compare, no gather) and logsumexp reduces over the
    sharded vocab dim -- under GSPMD both become tiny (B,S) all-reduces.
    A ``take_along_axis`` here would all-gather the full f32 logits
    (measured: 33.6 GB/chip on llama3.2-1b train_4k) -- see
    EXPERIMENTS.md §Perf.
    """
    logits = logits.astype(F32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    sumexp = jnp.exp(shifted).sum(-1)
    lse = jnp.log(sumexp) + m[..., 0]
    onehot = (labels[..., None] ==
              jnp.arange(logits.shape[-1])[None, None]).astype(F32)
    gold = (logits * onehot).sum(-1)
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    if mask is None:
        return nll.mean()
    mask = mask.astype(F32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
