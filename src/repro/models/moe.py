"""Mixture-of-Experts: top-k router + grouped capacity (GShard) dispatch.

Tokens are processed in contiguous *groups* (GShard's G x S layout).
Dispatch/combine one-hots are materialized per group, so their size is
O(S^2 * k * cf) per group instead of O(tokens * E * C) globally; with the
default group of 1024 tokens the dispatch overhead is ~3% of expert
FLOPs and the one-hot contractions lower to MXU matmuls.  The group dim
is token-major, so it inherits the batch sharding over ``data`` and the
expert-sharded einsums produce the canonical all-to-all pattern.

FLOPs scale with ``capacity_factor * top_k``, not ``n_experts`` -- the
compiled cost analysis therefore reflects *active* compute, which is
what the MoE roofline rows must show.

Expert padding (DESIGN.md §4): when ``n_experts`` is not divisible by
the model-axis size (qwen2-moe: 60 % 16 != 0), experts are padded to the
next multiple with dummies the router can never select (logits masked to
-inf).  The padding count is surfaced in ``sharding_report``.

Shared experts (qwen2-moe) run densely beside the routed path.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .params import Axes, ParamDef, Schema

F32 = jnp.float32

EP_HINT = 16        # production model-axis size; pad experts to this
GROUP_TOKENS = 1024


def padded_experts(cfg: ArchConfig, hint: int = EP_HINT) -> int:
    e = cfg.n_experts
    if e % hint == 0 or e < hint:
        return e
    return -(-e // hint) * hint


def moe_schema(cfg: ArchConfig, axes: Axes) -> Schema:
    d, f = cfg.d_model, cfg.d_ff_expert
    e_pad = padded_experts(cfg)
    ep = axes.tp if (axes.tp and e_pad % EP_HINT == 0) else None
    sch: Schema = {
        "router": ParamDef((d, e_pad), P(axes.fsdp, None)),
        "wi": ParamDef((e_pad, d, f), P(ep, axes.fsdp, None)),
        "wg": ParamDef((e_pad, d, f), P(ep, axes.fsdp, None)),
        "wo": ParamDef((e_pad, f, d), P(ep, None, axes.fsdp)),
    }
    if cfg.n_shared_experts:
        sch["shared"] = {
            "wi": ParamDef((d, cfg.n_shared_experts * f), P(axes.fsdp, axes.tp)),
            "wg": ParamDef((d, cfg.n_shared_experts * f), P(axes.fsdp, axes.tp)),
            "wo": ParamDef((cfg.n_shared_experts * f, d), P(axes.tp, axes.fsdp)),
            "gate": ParamDef((d, 1), P(axes.fsdp, None), init="zeros"),
        }
    return sch


def _group_size(n_tokens: int, want: int = GROUP_TOKENS) -> int:
    g = min(want, n_tokens)
    while n_tokens % g:
        g -= 1
    return g


def moe_apply(params: Schema, x: jax.Array, cfg: ArchConfig,
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    e_pad = params["router"].shape[-1]
    e_real = cfg.n_experts
    k = cfg.experts_per_token
    n = b * s
    sg = _group_size(n)
    g = n // sg
    cap = max(int(cfg.capacity_factor * k * sg / e_pad), 4)
    xt = x.reshape(g, sg, d)

    logits = jnp.einsum("gsd,de->gse", xt, params["router"],
                        preferred_element_type=F32)
    if e_pad != e_real:                        # dummy experts unroutable
        pad_mask = jnp.arange(e_pad) >= e_real
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (g,sg,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # per-group position of each (token, choice) in its expert's buffer
    onehot = jax.nn.one_hot(expert_idx, e_pad, dtype=jnp.int32)  # (g,sg,k,e)
    flat = onehot.reshape(g, sg * k, e_pad)
    pos = (jnp.cumsum(flat, axis=1) - 1).reshape(g, sg, k, e_pad)
    pos = (pos * onehot).sum(-1)                                # (g,sg,k)
    keep = pos < cap

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=x.dtype)[..., :cap]           # drop overflow
    disp = jnp.einsum("gske,gskc->gsec", onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum("gske,gskc,gsk->gsec", onehot.astype(F32),
                      pos_oh.astype(F32), gate_vals)

    xe = jnp.einsum("gsd,gsec->gecd", xt, disp)                 # (g,e,cap,d)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"],
                   preferred_element_type=F32)
    gt = jnp.einsum("gecd,edf->gecf", xe, params["wg"],
                    preferred_element_type=F32)
    h = (act(gt) * h).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"],
                    preferred_element_type=F32)                 # (g,e,cap,d)
    out = jnp.einsum("gsec,gecd->gsd", comb, ye.astype(F32))

    # load-balance auxiliary loss (Switch-style), real experts only
    me = probs[..., :e_real].mean((0, 1))
    ce = (onehot.sum(2)[..., :e_real] > 0).astype(F32).mean((0, 1))
    aux = cfg.router_aux_coef * e_real * jnp.sum(me * ce)

    out = out.astype(x.dtype).reshape(b, s, d)
    if "shared" in params:
        sh = params["shared"]
        hs = jnp.einsum("bsd,df->bsf", x, sh["wi"],
                        preferred_element_type=F32)
        gs = jnp.einsum("bsd,df->bsf", x, sh["wg"],
                        preferred_element_type=F32)
        ys = jnp.einsum("bsf,fd->bsd", (act(gs) * hs).astype(x.dtype),
                        sh["wo"], preferred_element_type=F32)
        sgate = jax.nn.sigmoid(
            jnp.einsum("bsd,dg->bsg", x, sh["gate"],
                       preferred_element_type=F32))
        out = out + (ys * sgate).astype(x.dtype)

    return out, aux
