"""Parameter schema: one declaration -> init + sharding + shape stand-ins.

Every module declares its parameters as a nested dict of :class:`ParamDef`
(shape, initializer, PartitionSpec).  From that single schema we derive:

* ``init_params``   -- materialized arrays (for real runs / smoke tests),
* ``param_specs``   -- the PartitionSpec pytree (for pjit in_shardings),
* ``param_shapes``  -- ShapeDtypeStruct stand-ins (for the dry-run; no
  allocation ever happens for the full-size configs),
* ``stack_schema``  -- prepend a layer axis L to every leaf (scan-over-
  layers stacking; the new axis is never sharded).

Keeping all four views in one schema is what makes the 40-cell dry-run
tractable: a sharding change is one edit, provably consistent everywhere.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Schema = Dict[str, Union["ParamDef", "Schema"]]


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P
    init: str = "fan_in"          # fan_in|normal|zeros|ones|small
    fan_in_axes: Tuple[int, ...] = (0,)   # axes whose product is fan-in
    scale: float = 1.0
    dtype: Optional[str] = None   # None -> caller-supplied default

    def with_layer_axis(self, n_layers: int) -> "ParamDef":
        return replace(
            self,
            shape=(n_layers,) + self.shape,
            spec=P(*((None,) + tuple(self.spec))),
            fan_in_axes=tuple(a + 1 for a in self.fan_in_axes),
        )

    def resolve_dtype(self, default):
        return jnp.dtype(self.dtype) if self.dtype else default


def _init_leaf(key: jax.Array, d: ParamDef, dtype) -> jax.Array:
    dtype = d.resolve_dtype(dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "const":
        return jnp.full(d.shape, d.scale, dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape) * d.scale).astype(dtype)
    if d.init == "small":
        return (jax.random.normal(key, d.shape) * 0.02 * d.scale).astype(dtype)
    if d.init == "fan_in":
        fan = 1
        for a in d.fan_in_axes:
            fan *= d.shape[a]
        std = d.scale / max(fan, 1) ** 0.5
        return (jax.random.normal(key, d.shape) * std).astype(dtype)
    raise ValueError(f"unknown init {d.init!r}")


def is_leaf(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(schema: Schema, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_leaf)
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_leaf(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def param_specs(schema: Schema):
    return jax.tree.map(lambda d: d.spec, schema, is_leaf=is_leaf)


def param_shapes(schema: Schema, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.resolve_dtype(dtype)),
        schema, is_leaf=is_leaf)


def stack_schema(schema: Schema, n_layers: int) -> Schema:
    return jax.tree.map(
        lambda d: d.with_layer_axis(n_layers), schema, is_leaf=is_leaf)


def count_params(schema: Schema) -> int:
    total = 0
    for d in jax.tree.leaves(schema, is_leaf=is_leaf):
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total


def bytes_of(schema: Schema, bytes_per_el: int = 2) -> int:
    return count_params(schema) * bytes_per_el


@dataclass(frozen=True)
class Axes:
    """Logical -> mesh axis mapping (DESIGN.md §4).

    ``fsdp`` shards parameters/optimizer state (the "data" mesh axis);
    ``tp`` shards heads / d_ff / vocab / experts (the "model" axis);
    ``batch`` is what activations' leading dim shards over -- ("pod",
    "data") on the multi-pod mesh, ("data",) on one pod.
    """

    fsdp: Optional[str] = "data"
    tp: Optional[str] = "model"
    batch: Tuple[str, ...] = ("data",)

    def batch_spec(self, *rest) -> P:
        return P(self.batch if len(self.batch) > 1 else self.batch[0], *rest)


SINGLE_POD_AXES = Axes(batch=("data",))
MULTI_POD_AXES = Axes(batch=("pod", "data"))
UNSHARDED_AXES = Axes(fsdp=None, tp=None, batch=(None,))


def shard_act(x: jax.Array, spec: P) -> jax.Array:
    """Constrain an activation's sharding (no-op without an active mesh).

    GSPMD resolves the FSDP conflict -- activations batch-sharded and
    weights contracting-dim-sharded on the SAME axis -- by whichever
    re-shard its cost model likes, and on the 16x16 mesh it picks
    replicating the activations (measured: full-batch f32 tensors
    all-reduced over ``data``, +100 GB/chip).  Pinning activations to
    batch sharding forces the correct choice: per-layer weight
    all-gather, the canonical FSDP schedule.
    """
    try:
        names = _ambient_axis_names()
        if not names:
            return x
        needed = {a for part in spec if part for a in
                  ((part,) if isinstance(part, str) else part)}
        if not needed.issubset(set(names)):
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def _ambient_axis_names() -> tuple:
    """Axis names of whichever ambient mesh is active, if any.

    Newer jax exposes the abstract mesh set by ``jax.set_mesh``; on
    older releases the ``with mesh:`` context manager populates the
    legacy thread-resources env instead -- check both so activation
    pinning works under either idiom.
    """
    from jax._src import mesh as mesh_lib
    try:
        names = tuple(mesh_lib.get_abstract_mesh().axis_names)
        if names:
            return names
    except Exception:
        pass
    try:
        return tuple(mesh_lib.thread_resources.env.physical_mesh.axis_names)
    except Exception:
        return ()


def axes_for(mesh) -> Axes:
    names = tuple(mesh.axis_names)
    if "pod" in names:
        return MULTI_POD_AXES
    if "data" in names and "model" in names:
        return SINGLE_POD_AXES
    return UNSHARDED_AXES
