"""Model assembly: every assigned architecture family from one toolbox.

A :class:`Model` is built from an :class:`~repro.configs.base.ArchConfig`
and exposes:

* ``schema()`` / ``init()`` / ``specs()``  -- parameters (one source of
  truth for shapes, init, sharding; see models/params.py),
* ``forward(params, batch)``               -- train/prefill logits + aux,
* ``loss(params, batch)``                  -- CE + z-loss + MoE aux.

Decode (KV-cache / recurrent-state serving) lives in models/decode.py.

Families (DESIGN.md §5):

dense / moe     -- one homogeneous decoder scan.
gemma3-style    -- the 5:1 local:global window schedule is structural:
                   scan over groups of (period-1 local layers + 1 global
                   layer) + a local tail, so every window is a *static*
                   Python int (no traced masks, no double compute) while
                   params remain exactly the published stack.
vlm             -- nested scan: groups of N self layers + 1 gated
                   cross-attention layer (llama-3.2-vision structure).
audio (enc-dec) -- whisper: bidirectional encoder over stub frame
                   embeddings + decoder with per-layer cross-attn.
ssm             -- xlstm: alternating mLSTM/sLSTM block pairs, scanned.
hybrid          -- hymba: attention and Mamba in parallel per layer,
                   fused by mean of RMS-normalized branch outputs; same
                   grouped window schedule as gemma3.

Remat: each scanned layer body is wrapped in ``jax.checkpoint`` with a
configurable policy ("full" | "dots" | "none") -- the §Perf activation-
memory knob.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from . import ssm
from .attention import (attention_chunked, attention_dense, attention_schema,
                        make_mask, out_project, qkv_project)
from .layers import (apply_mlp, apply_norm, cross_entropy, embed_tokens,
                     embedding_schema, mlp_schema, norm_schema, unembed)
from .moe import moe_apply, moe_schema
from .params import (Axes, ParamDef, Schema, init_params, param_shapes,
                     param_specs, shard_act, stack_schema)

F32 = jnp.float32


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)          # "full": save only layer boundaries


@dataclass
class Model:
    cfg: ArchConfig
    axes: Axes = field(default_factory=Axes)
    remat: str = "full"
    attn_impl: str = "auto"            # auto|dense|chunked
    attn_chunk: int = 1024

    # ------------------------------------------------------------------ #
    # schema
    # ------------------------------------------------------------------ #
    def schema(self) -> Schema:
        cfg = self.cfg
        sch: Schema = {"embed": embedding_schema(cfg, self.axes),
                       "final_norm": norm_schema(cfg)}
        fam = cfg.family
        layer = (self._hybrid_layer_schema() if fam == "hybrid"
                 else self._self_layer_schema())
        if fam in ("dense", "moe", "hybrid"):
            sch["layers"] = self._windowed_stack_schema(layer)
        elif fam == "vlm":
            g = cfg.cross_attn_group
            sch["layers"] = stack_schema(
                {"selfs": stack_schema(layer, g),
                 "cross": self._cross_layer_schema()},
                cfg.n_layers // g)
        elif fam == "audio":
            sch["enc_layers"] = stack_schema(layer, cfg.n_encoder_layers)
            sch["enc_norm"] = norm_schema(cfg)
            sch["layers"] = stack_schema(
                self._decoder_cross_layer_schema(), cfg.n_layers)
        elif fam == "ssm":
            pair: Schema = {}
            for i, kind in enumerate(cfg.block_pattern):
                blk = (ssm.mlstm_schema(cfg, self.axes) if kind == "mlstm"
                       else ssm.slstm_schema(cfg, self.axes))
                pair[f"{i}_{kind}"] = {"norm": norm_schema(cfg), "block": blk}
            sch["layers"] = stack_schema(
                pair, cfg.n_layers // len(cfg.block_pattern))
        else:
            raise ValueError(f"unknown family {fam}")
        return sch

    def _windowed_stack_schema(self, layer: Schema) -> Schema:
        cfg = self.cfg
        period = cfg.global_every
        if not (cfg.sliding_window and period) or cfg.n_layers < period:
            return {"flat": stack_schema(layer, cfg.n_layers)}
        n_groups, n_tail = divmod(cfg.n_layers, period)
        sch: Schema = {"groups": stack_schema(
            {"locals": stack_schema(layer, period - 1), "glob": layer},
            n_groups)}
        if n_tail:
            sch["tail"] = stack_schema(layer, n_tail)
        return sch

    def _self_layer_schema(self) -> Schema:
        cfg, axes = self.cfg, self.axes
        sch: Schema = {
            "attn_norm": norm_schema(cfg),
            "attn": attention_schema(cfg, axes),
            "mlp_norm": norm_schema(cfg),
        }
        if cfg.is_moe:
            sch["moe"] = moe_schema(cfg, axes)
        else:
            sch["mlp"] = mlp_schema(cfg, axes)
        return sch

    def _cross_layer_schema(self) -> Schema:
        cfg, axes = self.cfg, self.axes
        return {
            "attn_norm": norm_schema(cfg),
            "attn": attention_schema(cfg, axes, cross=True),
            "mlp_norm": norm_schema(cfg),
            "mlp": mlp_schema(cfg, axes),
            "gate": ParamDef((1,), P(None), init="zeros"),
        }

    def _decoder_cross_layer_schema(self) -> Schema:
        sch = self._self_layer_schema()
        sch["cross_norm"] = norm_schema(self.cfg)
        sch["cross"] = attention_schema(self.cfg, self.axes, cross=True)
        return sch

    def _hybrid_layer_schema(self) -> Schema:
        cfg, axes = self.cfg, self.axes
        return {
            "norm": norm_schema(cfg),
            "attn": attention_schema(cfg, axes),
            "mamba": ssm.mamba_schema(cfg, axes),
            "mlp_norm": norm_schema(cfg),
            "mlp": mlp_schema(cfg, axes),
        }

    def init(self, key: jax.Array, dtype=jnp.float32):
        return init_params(self.schema(), key, dtype)

    def specs(self):
        return param_specs(self.schema())

    def shapes(self, dtype=jnp.bfloat16):
        return param_shapes(self.schema(), dtype)

    # ------------------------------------------------------------------ #
    # forward (train / prefill)
    # ------------------------------------------------------------------ #
    def forward(self, params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, jax.Array]:
        """-> (logits (B,S,V), aux_loss scalar)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens, cfg,
                         dtype=self._adtype(params))
        x = self._cact(x)
        pos = jnp.arange(tokens.shape[1])
        aux0 = jnp.zeros((), F32)
        fam = cfg.family

        if fam in ("dense", "moe"):
            x, aux = self._run_windowed(params["layers"], x, aux0, pos,
                                        self._self_layer)
        elif fam == "hybrid":
            x, aux = self._run_windowed(params["layers"], x, aux0, pos,
                                        self._hybrid_layer)
        elif fam == "vlm":
            x, aux = self._run_vlm(params["layers"], x, aux0, pos,
                                   batch["images"])
        elif fam == "audio":
            enc = self._run_encoder(params, batch["frames"])
            x, aux = self._run_audio_decoder(params["layers"], x, aux0,
                                             pos, enc)
        elif fam == "ssm":
            x = self._run_ssm_stack(params["layers"], x)
            aux = aux0
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)
        if logits.shape[0] > 1:
            logits = shard_act(
                logits, self.axes.batch_spec(None, self.axes.tp))
        return logits, aux

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """CE + aux losses.  ``batch["labels"]``, when present, is already
        position-aligned (``labels[i]`` is the target for position ``i`` --
        the pipeline emits next-token labels); only the ``tokens`` fallback
        needs the one-position shift.  Shifting provided labels again would
        silently train a predict-two-ahead objective.
        """
        logits, aux = self.forward(params, batch)
        labels = batch.get("labels")
        if labels is None:
            ce = cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
        else:
            ce = cross_entropy(logits, labels)
        return ce + aux, {"ce": ce, "aux": aux}

    # ---- attention plumbing ---------------------------------------------------
    def _adtype(self, params):
        return jax.tree.leaves(params["embed"])[0].dtype

    def _cact(self, x):
        """Pin activations to batch sharding (see params.shard_act).

        Decode exception (``_replicate_acts``): one-token activations are
        tiny (batch x d_model), while FSDP weight all-gathers cost
        ~params/TP per step (mistral decode_32k: 29.7 GB/chip/step).
        Replicating the activations flips the resolution: weights stay
        fully 256-way sharded and each matmul psums a few hundred KB --
        the weight-stationary serving layout (EXPERIMENTS.md §Perf C2).
        """
        if getattr(self, "_replicate_acts", False):
            return shard_act(x, P(*([None] * x.ndim)))
        if x.shape[0] == 1:
            return x
        if getattr(self, "seq_parallel", False) and x.ndim == 3:
            # sequence parallelism (Megatron SP): activations in the
            # norm/residual regions shard their SEQ dim over ``model``,
            # so the per-layer TP combine lowers to reduce-scatter (+
            # all-gather at the next attention/MLP entry) -- half the
            # bytes of the plain all-reduce (§Perf B2).
            return shard_act(x, self.axes.batch_spec(self.axes.tp, None))
        return shard_act(x, self.axes.batch_spec(
            *([None] * (x.ndim - 1))))

    def _attend(self, p, x, q_pos, k_pos, window: int, *, causal=True,
                xkv=None, rope=True):
        cfg = self.cfg
        q, k, v = qkv_project(p, x, x if xkv is None else xkv, cfg,
                              q_positions=q_pos, k_positions=k_pos,
                              rope=rope)
        sq, skv = q.shape[1], k.shape[1]
        impl = self.attn_impl
        if impl == "auto":
            impl = "dense" if sq * skv <= 2048 * 2048 else "chunked"
        if impl == "dense":
            mask = (make_mask(q_pos, k_pos, causal=causal, window=window)
                    if (causal or window) else None)
            o = attention_dense(q, k, v, mask, cfg)
        else:
            o = attention_chunked(q, k, v, q_pos, k_pos, cfg, causal=causal,
                                  window=window, chunk=self.attn_chunk)
        return out_project(p, o, x.dtype)

    # ---- layer bodies -----------------------------------------------------------
    def _gather_sp(self, h):
        """Megatron-SP all-gather point: TP-region inputs need the full
        sequence; residual stays seq-sharded so the TP output combine
        lowers to reduce-scatter instead of all-reduce."""
        if getattr(self, "seq_parallel", False) and h.shape[0] > 1 \
                and h.ndim == 3:
            return shard_act(h, self.axes.batch_spec(None, None))
        return h

    def _self_layer(self, p, x, aux, pos, window: int):
        cfg = self.cfg
        x = self._cact(x)
        h = self._gather_sp(apply_norm(p["attn_norm"], x, cfg))
        x = x + self._attend(p["attn"], h, pos, pos, window)
        h = self._gather_sp(apply_norm(p["mlp_norm"], x, cfg))
        if cfg.is_moe:
            h, a = moe_apply(p["moe"], h, cfg)
            aux = aux + a
        else:
            h = apply_mlp(p["mlp"], h, cfg)
        return x + h, aux

    def _hybrid_layer(self, p, x, aux, pos, window: int):
        cfg = self.cfg
        x = self._cact(x)
        h = apply_norm(p["norm"], x, cfg)
        a = self._attend(p["attn"], h, pos, pos, window)
        m = ssm.mamba_apply(p["mamba"], h, cfg)
        fused = 0.5 * (_rms(a.astype(F32)) + _rms(m.astype(F32)))
        x = x + fused.astype(x.dtype)
        h = apply_norm(p["mlp_norm"], x, cfg)
        return x + apply_mlp(p["mlp"], h, cfg), aux

    # ---- stacks ----------------------------------------------------------------
    def _scan_layers(self, layer_fn, stacked, x, aux, pos, window: int):
        def body(carry, p):
            x, aux = carry
            return layer_fn(p, x, aux, pos, window), None

        (x, aux), _ = jax.lax.scan(_remat(body, self.remat), (x, aux),
                                   stacked)
        return x, aux

    def _run_windowed(self, params, x, aux, pos, layer_fn):
        cfg = self.cfg
        w = int(cfg.sliding_window)
        if "flat" in params:
            x, aux = self._scan_layers(layer_fn, params["flat"], x, aux,
                                       pos, w)
        else:
            def group(carry, p):
                x, aux = carry
                x, aux = self._scan_layers(layer_fn, p["locals"], x, aux,
                                           pos, w)
                x, aux = layer_fn(p["glob"], x, aux, pos, 0)
                return (x, aux), None

            (x, aux), _ = jax.lax.scan(_remat(group, self.remat), (x, aux),
                                       params["groups"])
            if "tail" in params:
                x, aux = self._scan_layers(layer_fn, params["tail"], x, aux,
                                           pos, w)
        if cfg.is_moe:
            aux = aux / max(cfg.n_layers, 1)
        return x, aux

    def _run_vlm(self, params, x, aux, pos, images):
        cfg = self.cfg
        img = images.astype(x.dtype)
        img_pos = jnp.arange(img.shape[1])

        def group(carry, p):
            x, aux = carry
            x = self._cact(x)
            x, aux = self._scan_layers(self._self_layer, p["selfs"], x, aux,
                                       pos, int(cfg.sliding_window))
            pc = p["cross"]
            h = apply_norm(pc["attn_norm"], x, cfg)
            h = self._attend(pc["attn"], h, pos, img_pos, 0, causal=False,
                             xkv=img, rope=False)
            x = x + jnp.tanh(pc["gate"].astype(F32)).astype(x.dtype) * h
            h = apply_norm(pc["mlp_norm"], x, cfg)
            x = x + apply_mlp(pc["mlp"], h, cfg)
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(_remat(group, self.remat), (x, aux),
                                   params)
        return x, aux

    def _run_encoder(self, params, frames):
        cfg = self.cfg
        x = frames.astype(self._adtype(params))
        pos = jnp.arange(x.shape[1])

        def layer(carry, p):
            x, aux = carry
            x = self._cact(x)
            h = apply_norm(p["attn_norm"], x, cfg)
            x = x + self._attend(p["attn"], h, pos, pos, 0, causal=False)
            h = apply_norm(p["mlp_norm"], x, cfg)
            return (x + apply_mlp(p["mlp"], h, cfg), aux), None

        (x, _), _ = jax.lax.scan(_remat(layer, self.remat),
                                 (x, jnp.zeros((), F32)),
                                 params["enc_layers"])
        return apply_norm(params["enc_norm"], x, cfg)

    def _run_audio_decoder(self, params, x, aux, pos, enc):
        cfg = self.cfg
        enc_pos = jnp.arange(enc.shape[1])

        def layer(carry, p):
            x, aux = carry
            x = self._cact(x)
            h = apply_norm(p["attn_norm"], x, cfg)
            x = x + self._attend(p["attn"], h, pos, pos, 0)
            h = apply_norm(p["cross_norm"], x, cfg)
            x = x + self._attend(p["cross"], h, pos, enc_pos, 0,
                                 causal=False, xkv=enc, rope=False)
            h = apply_norm(p["mlp_norm"], x, cfg)
            return (x + apply_mlp(p["mlp"], h, cfg), aux), None

        (x, aux), _ = jax.lax.scan(_remat(layer, self.remat), (x, aux),
                                   params)
        return x, aux

    def _run_ssm_stack(self, stacked, x):
        cfg = self.cfg

        def pair(carry, p):
            x = self._cact(carry)
            for i, kind in enumerate(cfg.block_pattern):
                blk = p[f"{i}_{kind}"]
                h = apply_norm(blk["norm"], x, cfg)
                if kind == "mlstm":
                    h = ssm.mlstm_apply(blk["block"], h, cfg)
                else:
                    h = ssm.slstm_apply(blk["block"], h, cfg)
                x = x + h
            return x, None

        x, _ = jax.lax.scan(_remat(pair, self.remat), x, stacked)
        return x


def _rms(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return x * jax.lax.rsqrt((x * x).mean(-1, keepdims=True) + eps)
