"""Serving decode: KV caches / recurrent state + one-token step.

``state_schema(model, batch, max_len)`` declares the decode state with
the same ParamDef machinery as parameters, so shapes, shardings, and
ShapeDtypeStruct stand-ins stay consistent across smoke tests and the
512-device dry-run.  The state pytree mirrors the parameter stack
structure (flat / grouped / nested), letting one ``lax.scan`` walk
params and cache slices together.

Sharding of caches (DESIGN.md §4):

* batch > 1: cache batch dim shards over the batch axes.
* batch == 1 (long_500k): the *sequence* dim shards over ``data``
  (ring layout); softmax over the sharded dim becomes an XLA
  all-reduce of partial (max, sum, weighted-V) -- visible in the
  collective roofline term.
* KV heads shard over ``model`` only when divisible; SSM states shard
  their channel dim.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from . import ssm
from .attention import (_attn_tp, attention_decode, out_project,
                        qkv_project, update_kv_cache)
from .layers import apply_mlp, apply_norm, embed_tokens, unembed
from .moe import moe_apply
from .params import (Axes, ParamDef, Schema, init_params, param_shapes,
                     param_specs, stack_schema)
from .transformer import Model, _rms

F32 = jnp.float32


# ---------------------------------------------------------------------------
# State schema
# ---------------------------------------------------------------------------

def _batch_axis(axes: Axes, batch: int):
    if batch == 1:
        return None
    return axes.batch if len(axes.batch) > 1 else axes.batch[0]


def _kv_def(cfg: ArchConfig, axes: Axes, batch: int, seq: int,
            kv_heads: Optional[int] = None,
            cache_dtype: str = "bfloat16") -> ParamDef:
    """(B, S, KV, hd) cache leaf.

    Decode caches are the largest serving tensors (mistral decode_32k:
    ~1.5 TB global), so both mesh axes must carve them:

    * batch > 1: batch shards over ``data``; KV heads shard over
      ``model`` when divisible, otherwise the *sequence* dim shards over
      ``model`` (attention softmax then reduces over a sharded dim --
      XLA inserts the partial-softmax all-reduce; §Roofline shows it).
    * batch == 1 (long_500k): sequence shards over every available axis.
    """
    _, kv_tp = _attn_tp(cfg, axes)
    kv = kv_heads or cfg.n_kv_heads
    if batch == 1:
        seq_axes = [a for a in (axes.fsdp, axes.tp if kv_tp is None else
                                None) if a]
        seq_sharding = (tuple(seq_axes) if len(seq_axes) > 1 else
                        (seq_axes[0] if seq_axes else None))
        spec = P(None, seq_sharding, kv_tp, None)
    else:
        seq_ax = axes.tp if kv_tp is None else None
        spec = P(_batch_axis(axes, batch), seq_ax, kv_tp, None)
    return ParamDef((batch, seq, kv, cfg.head_dim), spec, init="zeros",
                    dtype=cache_dtype)


def _self_cache(cfg: ArchConfig, axes: Axes, batch: int, seq: int,
                cache_dtype: str = "bfloat16") -> Schema:
    return {"k": _kv_def(cfg, axes, batch, seq, cache_dtype=cache_dtype),
            "v": _kv_def(cfg, axes, batch, seq, cache_dtype=cache_dtype)}


def _mamba_state(cfg: ArchConfig, axes: Axes, batch: int) -> Schema:
    inner = cfg.ssm_expand * cfg.d_model
    tp = axes.tp if (axes.tp and inner % 16 == 0) else None
    ba = _batch_axis(axes, batch)
    return {
        "h": ParamDef((batch, inner, cfg.ssm_state), P(ba, tp, None),
                      init="zeros", dtype="float32"),
        "conv": ParamDef((batch, cfg.ssm_conv - 1, inner), P(ba, None, tp),
                         init="zeros", dtype="float32"),
    }


def _mlstm_state(cfg: ArchConfig, axes: Axes, batch: int) -> Schema:
    inner = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    hd = inner // h
    tp = axes.tp if (axes.tp and hd % 16 == 0) else None
    ba = _batch_axis(axes, batch)
    return {
        "c": ParamDef((batch, h, hd, hd), P(ba, None, None, tp),
                      init="zeros", dtype="float32"),
        "n": ParamDef((batch, h, hd), P(ba, None, None), init="zeros",
                      dtype="float32"),
        "m": ParamDef((batch, h), P(ba, None), init="const", scale=-1e30,
                      dtype="float32"),
    }


def _slstm_state(cfg: ArchConfig, axes: Axes, batch: int) -> Schema:
    h = cfg.n_heads
    hd = cfg.d_model // h
    ba = _batch_axis(axes, batch)
    sch = {k: ParamDef((batch, h, hd), P(ba, None, None), init="zeros",
                       dtype="float32")
           for k in ("c", "n", "h")}
    sch["m"] = ParamDef((batch, h, hd), P(ba, None, None), init="const",
                        scale=-1e30, dtype="float32")
    return sch


def state_schema(model: Model, batch: int, max_len: int,
                 cache_dtype: str = "bfloat16") -> Schema:
    """Decode-state declaration for one (arch, batch, max_len)."""
    cfg, axes = model.cfg, model.axes
    fam = cfg.family
    # per-sequence positions: continuous batching serves mixed-progress
    # sequences from one compiled program
    sch: Schema = {"pos": ParamDef((batch,), P(_batch_axis(axes, batch)),
                                   init="zeros", dtype="int32")}
    if fam in ("dense", "moe"):
        sch["layers"] = _stack_like_params(
            model, _self_cache(cfg, axes, batch, max_len, cache_dtype))
    elif fam == "hybrid":
        per_layer = {"attn": _self_cache(cfg, axes, batch, max_len,
                                        cache_dtype),
                     "mamba": _mamba_state(cfg, axes, batch)}
        sch["layers"] = _stack_like_params(model, per_layer)
    elif fam == "ssm":
        pair: Schema = {}
        for i, kind in enumerate(cfg.block_pattern):
            pair[f"{i}_{kind}"] = (_mlstm_state(cfg, axes, batch)
                                   if kind == "mlstm"
                                   else _slstm_state(cfg, axes, batch))
        sch["layers"] = stack_schema(pair,
                                     cfg.n_layers // len(cfg.block_pattern))
    elif fam == "vlm":
        g = cfg.cross_attn_group
        n_groups = cfg.n_layers // g
        sch["layers"] = stack_schema(
            {"selfs": stack_schema(
                _self_cache(cfg, axes, batch, max_len, cache_dtype), g),
             "cross_k": _kv_def(cfg, axes, batch, cfg.vision_tokens,
                                cache_dtype=cache_dtype),
             "cross_v": _kv_def(cfg, axes, batch, cfg.vision_tokens,
                                cache_dtype=cache_dtype)},
            n_groups)
    elif fam == "audio":
        enc_len = cfg.vision_tokens                # encoder frames
        sch["enc_len"] = ParamDef((), P(), init="zeros", dtype="int32")
        sch["layers"] = stack_schema(
            {**_self_cache(cfg, axes, batch, max_len, cache_dtype),
             "cross_k": _kv_def(cfg, axes, batch, enc_len,
                                cache_dtype=cache_dtype),
             "cross_v": _kv_def(cfg, axes, batch, enc_len,
                                cache_dtype=cache_dtype)},
            cfg.n_layers)
    else:
        raise ValueError(fam)
    return sch


def _stack_like_params(model: Model, per_layer: Schema) -> Schema:
    """Mirror the windowed group/tail structure of the param stack."""
    cfg = model.cfg
    period = cfg.global_every
    if not (cfg.sliding_window and period) or cfg.n_layers < period:
        return {"flat": stack_schema(per_layer, cfg.n_layers)}
    n_groups, n_tail = divmod(cfg.n_layers, period)
    sch: Schema = {"groups": stack_schema(
        {"locals": stack_schema(per_layer, period - 1), "glob": per_layer},
        n_groups)}
    if n_tail:
        sch["tail"] = stack_schema(per_layer, n_tail)
    return sch


def init_state(model: Model, batch: int, max_len: int,
               key: Optional[jax.Array] = None,
               cache_dtype: str = "bfloat16"):
    return init_params(state_schema(model, batch, max_len, cache_dtype),
                       key if key is not None else jax.random.key(0),
                       jnp.float32)


def state_specs(model: Model, batch: int, max_len: int):
    return param_specs(state_schema(model, batch, max_len))


def state_shapes(model: Model, batch: int, max_len: int):
    return param_shapes(state_schema(model, batch, max_len), jnp.bfloat16)


# ---------------------------------------------------------------------------
# One-token decode step
# ---------------------------------------------------------------------------

def decode_step(model: Model, params, state, tokens: jax.Array,
                uniform_pos: bool = False) -> Tuple[jax.Array, Dict]:
    """tokens: (B, 1) -> (logits (B, 1, V), new state).

    ``uniform_pos=True``: all sequences share one position (bulk
    benchmark decode) -- enables the copy-free single-DUS cache update
    (see attention.update_kv_cache).
    """
    cfg = model.cfg
    model._uniform_pos = uniform_pos
    fam = cfg.family
    pos = state["pos"]
    x = embed_tokens(params["embed"], tokens, cfg,
                     dtype=model._adtype(params))
    x = model._cact(x)
    q_pos = pos[:, None].astype(jnp.int32)        # (B,1) rope positions

    if fam in ("dense", "moe"):
        x, layers = _decode_windowed(model, params["layers"],
                                     state["layers"], x, q_pos, pos,
                                     _self_layer_decode)
    elif fam == "hybrid":
        x, layers = _decode_windowed(model, params["layers"],
                                     state["layers"], x, q_pos, pos,
                                     _hybrid_layer_decode)
    elif fam == "ssm":
        x, layers = _decode_ssm(model, params["layers"], state["layers"], x)
    elif fam == "vlm":
        x, layers = _decode_vlm(model, params["layers"], state["layers"],
                                x, q_pos, pos)
    elif fam == "audio":
        x, layers = _decode_audio(model, params["layers"], state["layers"],
                                  x, q_pos, pos, state["enc_len"])
    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x, cfg)
    new_state = {"pos": pos + 1, "layers": layers}
    if "enc_len" in state:
        new_state["enc_len"] = state["enc_len"]
    return logits, new_state


def _self_layer_decode(model: Model, p, c, x, q_pos, pos, window: int):
    cfg = model.cfg
    h = apply_norm(p["attn_norm"], x, cfg)
    q, k, v = qkv_project(p["attn"], h, h, cfg, q_positions=q_pos,
                          k_positions=q_pos)
    kc, vc = update_kv_cache(c["k"], c["v"], k, v, pos,
                             uniform=getattr(model, "_uniform_pos", False))
    o = attention_decode(q, kc, vc, pos, cfg, window=window)
    x = x + out_project(p["attn"], o, x.dtype)
    h = apply_norm(p["mlp_norm"], x, cfg)
    if cfg.is_moe:
        h, _ = moe_apply(p["moe"], h, cfg)
    else:
        h = apply_mlp(p["mlp"], h, cfg)
    return x + h, {"k": kc, "v": vc}


def _hybrid_layer_decode(model: Model, p, c, x, q_pos, pos, window: int):
    cfg = model.cfg
    h = apply_norm(p["norm"], x, cfg)
    q, k, v = qkv_project(p["attn"], h, h, cfg, q_positions=q_pos,
                          k_positions=q_pos)
    kc, vc = update_kv_cache(c["attn"]["k"], c["attn"]["v"], k, v, pos,
                             uniform=getattr(model, "_uniform_pos", False))
    o = attention_decode(q, kc, vc, pos, cfg, window=window)
    a = out_project(p["attn"], o, x.dtype)
    m, hstate, conv = ssm.mamba_decode_step(
        p["mamba"], h, c["mamba"]["h"], c["mamba"]["conv"], cfg)
    fused = 0.5 * (_rms(a.astype(F32)) + _rms(m.astype(F32)))
    x = x + fused.astype(x.dtype)
    h = apply_norm(p["mlp_norm"], x, cfg)
    x = x + apply_mlp(p["mlp"], h, cfg)
    return x, {"attn": {"k": kc, "v": vc},
               "mamba": {"h": hstate, "conv": conv}}


def _decode_windowed(model: Model, params, caches, x, q_pos, pos, layer_fn):
    cfg = model.cfg
    w = int(cfg.sliding_window)

    def scan_stack(x, stack_p, stack_c, window):
        def body(x, xs):
            p, c = xs
            return layer_fn(model, p, c, x, q_pos, pos, window)
        return jax.lax.scan(body, x, (stack_p, stack_c))

    if "flat" in params:
        x, new = scan_stack(x, params["flat"], caches["flat"], w)
        return x, {"flat": new}

    def group(x, xs):
        p, c = xs
        x, new_loc = scan_stack(x, p["locals"], c["locals"], w)
        x, new_glob = layer_fn(model, p["glob"], c["glob"], x, q_pos, pos, 0)
        return x, {"locals": new_loc, "glob": new_glob}

    x, new_groups = jax.lax.scan(group, x, (params["groups"],
                                            caches["groups"]))
    out = {"groups": new_groups}
    if "tail" in params:
        x, new_tail = scan_stack(x, params["tail"], caches["tail"], w)
        out["tail"] = new_tail
    return x, out


def _decode_ssm(model: Model, params, caches, x):
    cfg = model.cfg

    def pair(x, xs):
        p, c = xs
        new = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"{i}_{kind}"
            blk, st = p[key], c[key]
            h = apply_norm(blk["norm"], x, cfg)
            if kind == "mlstm":
                h, new_st = ssm.mlstm_decode_step(blk["block"], h, st, cfg)
            else:
                h, new_st = ssm.slstm_decode_step(blk["block"], h, st, cfg)
            x = x + h
            new[key] = new_st
        return x, new

    return jax.lax.scan(pair, x, (params, caches))


def _decode_vlm(model: Model, params, caches, x, q_pos, pos):
    cfg = model.cfg

    def group(x, xs):
        p, c = xs

        def body(x, ys):
            lp, lc = ys
            return _self_layer_decode(model, lp, lc, x, q_pos, pos,
                                      int(cfg.sliding_window))

        x, new_selfs = jax.lax.scan(body, x, (p["selfs"], c["selfs"]))
        pc = p["cross"]
        h = apply_norm(pc["attn_norm"], x, cfg)
        q, _, _ = qkv_project(pc["attn"], h, h, cfg, rope=False)
        o = attention_decode(q, c["cross_k"], c["cross_v"],
                             jnp.asarray(c["cross_k"].shape[1] - 1), cfg)
        h = out_project(pc["attn"], o, x.dtype)
        x = x + jnp.tanh(pc["gate"].astype(F32)).astype(x.dtype) * h
        h = apply_norm(pc["mlp_norm"], x, cfg)
        x = x + apply_mlp(pc["mlp"], h, cfg)
        return x, {"selfs": new_selfs, "cross_k": c["cross_k"],
                   "cross_v": c["cross_v"]}

    return jax.lax.scan(group, x, (params, caches))


def _decode_audio(model: Model, params, caches, x, q_pos, pos, enc_len):
    cfg = model.cfg

    def layer(x, xs):
        p, c = xs
        h = apply_norm(p["attn_norm"], x, cfg)
        q, k, v = qkv_project(p["attn"], h, h, cfg, q_positions=q_pos,
                              k_positions=q_pos)
        kc, vc = update_kv_cache(c["k"], c["v"], k, v, pos,
                                 uniform=getattr(model, "_uniform_pos",
                                                 False))
        o = attention_decode(q, kc, vc, pos, cfg)
        x = x + out_project(p["attn"], o, x.dtype)
        h = apply_norm(p["cross_norm"], x, cfg)
        q, _, _ = qkv_project(p["cross"], h, h, cfg, rope=False)
        o = attention_decode(q, c["cross_k"], c["cross_v"], enc_len - 1, cfg)
        x = x + out_project(p["cross"], o, x.dtype)
        h = apply_norm(p["mlp_norm"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
        return x, {"k": kc, "v": vc, "cross_k": c["cross_k"],
                   "cross_v": c["cross_v"]}

    return jax.lax.scan(layer, x, (params, caches))


# ---------------------------------------------------------------------------
# Prefill -> state
# ---------------------------------------------------------------------------

def prefill(model: Model, params, batch: Dict[str, jax.Array],
            max_len: int) -> Tuple[jax.Array, Dict]:
    """Fill a decode state from a prompt; returns (last logits, state).

    Implemented by streaming the prompt through ``decode_step`` under a
    ``lax.scan`` -- exact for every family (attention caches and
    recurrent states alike), one compiled program, and the decode-path
    code is the single source of truth for cache layout.  Large-scale
    deployments lower ``model.forward`` for the prefill phase (that is
    what the prefill_32k dry-run cells measure); this streaming variant
    is the serving engine's state builder.

    For cross-attention families the static context (vision tokens /
    encoder output) is projected once up front.
    """
    cfg = model.cfg
    tokens = batch["tokens"]
    b, s = tokens.shape
    state = init_state(model, b, max_len)
    state = _attach_cross_context(model, params, state, batch)

    def step(state, tok):
        logits, state = decode_step(model, params, state, tok[:, None])
        return state, logits[:, 0]

    state, logits = jax.lax.scan(step, state, tokens.T)
    return logits[-1][:, None], state


def _attach_cross_context(model: Model, params, state, batch):
    """Project vision/encoder tokens into the cross-attention caches."""
    cfg = model.cfg
    if cfg.family == "vlm":
        img = batch["images"]

        def proj(p):
            _, ck, cv = qkv_project(p["cross"]["attn"],
                                    img.astype(jnp.bfloat16),
                                    img.astype(jnp.bfloat16), cfg,
                                    rope=False)
            return ck, cv

        ck, cv = jax.vmap(proj)(params["layers"])     # over groups
        layers = dict(state["layers"])
        cdt = layers["cross_k"].dtype
        layers["cross_k"], layers["cross_v"] = (
            ck.astype(cdt), cv.astype(cdt))
        state = dict(state)
        state["layers"] = layers
    elif cfg.family == "audio":
        enc = model._run_encoder(params, batch["frames"])
        cache_len = state["layers"]["cross_k"].shape[2]
        enc = enc[:, :cache_len]

        def proj(p):
            _, ck, cv = qkv_project(p["cross"], enc, enc, cfg, rope=False)
            return ck, cv

        ck, cv = jax.vmap(proj)(params["layers"])     # over decoder layers
        layers = dict(state["layers"])
        pad = cache_len - ck.shape[2]                 # left-align shorter enc
        if pad:
            padw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
            ck = jnp.pad(ck, padw)
            cv = jnp.pad(cv, padw)
        cdt = layers["cross_k"].dtype
        layers["cross_k"], layers["cross_v"] = (
            ck.astype(cdt), cv.astype(cdt))
        state = dict(state)
        state["layers"] = layers
        state["enc_len"] = jnp.asarray(enc.shape[1], jnp.int32)
    return state
