"""Grouped-query attention: schemas, train/prefill/decode paths, masks.

Three execution paths, one math:

* ``attention_dense``   -- materialized logits; smoke tests & tiny shapes.
* ``attention_chunked`` -- lax.scan over KV chunks with online softmax
  (flash-attention recurrence in pure JAX).  This is the default for
  large shapes: activation memory is O(S * chunk) instead of O(S^2), so
  the dry-run memory/roofline profile matches what the Pallas kernel
  (kernels/flash_attention) achieves on real TPUs.
* ``attention_decode``  -- one query token against a KV cache.

GQA sharding: query heads shard over the ``model`` axis when divisible;
KV projections stay replicated over ``model`` when ``n_kv_heads % tp != 0``
(Megatron-style KV replication, DESIGN.md §4) -- each shard then holds
full K/V and its slice of query heads, so no collective is needed inside
the attention body.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .layers import apply_rope
from .params import Axes, ParamDef, Schema

F32 = jnp.float32
NEG_INF = -1e30


def _attn_tp(cfg: ArchConfig, axes: Axes, tp_size_hint: int = 16):
    """(q_heads_axis, kv_heads_axis) honoring the divisibility policy."""
    if axes.tp is None or cfg.n_heads % tp_size_hint:
        return None, None
    kv_axis = axes.tp if cfg.n_kv_heads % tp_size_hint == 0 else None
    return axes.tp, kv_axis


def attention_schema(cfg: ArchConfig, axes: Axes, *,
                     cross: bool = False) -> Schema:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q_tp, kv_tp = _attn_tp(cfg, axes)
    sch: Schema = {
        "wq": ParamDef((d, h, hd), P(axes.fsdp, q_tp, None)),
        "wk": ParamDef((d, kv, hd), P(axes.fsdp, kv_tp, None)),
        "wv": ParamDef((d, kv, hd), P(axes.fsdp, kv_tp, None)),
        "wo": ParamDef((h, hd, d), P(q_tp, None, axes.fsdp)),
    }
    if cfg.qkv_bias and not cross:
        sch["bq"] = ParamDef((h, hd), P(q_tp, None), init="zeros")
        sch["bk"] = ParamDef((kv, hd), P(kv_tp, None), init="zeros")
        sch["bv"] = ParamDef((kv, hd), P(kv_tp, None), init="zeros")
    return sch


def qkv_project(params: Schema, xq: jax.Array, xkv: jax.Array,
                cfg: ArchConfig, q_positions: Optional[jax.Array] = None,
                k_positions: Optional[jax.Array] = None,
                rope: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"],
                   preferred_element_type=F32).astype(xq.dtype)
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"],
                   preferred_element_type=F32).astype(xq.dtype)
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"],
                   preferred_element_type=F32).astype(xq.dtype)
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if rope:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, k_positions, cfg.rope_theta)
    return q, k, v


def out_project(params: Schema, o: jax.Array, dtype) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"],
                      preferred_element_type=F32).astype(dtype)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def make_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
              window: int = 0) -> jax.Array:
    """(..., Sq, Skv) boolean mask; True = attend."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    return mask


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap:
        return cap * jnp.tanh(logits / cap)
    return logits


# ---------------------------------------------------------------------------
# Dense path (smoke tests, tiny shapes)
# ---------------------------------------------------------------------------

def attention_dense(q: jax.Array, k: jax.Array, v: jax.Array,
                    mask: Optional[jax.Array], cfg: ArchConfig) -> jax.Array:
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd); mask: (Sq,Skv) or None."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=F32) / (hd ** 0.5)
    logits = _softcap(logits, cfg.attn_logit_softcap)
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v, preferred_element_type=F32)
    return o.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax path (the flash recurrence in pure JAX)
# ---------------------------------------------------------------------------

class _Carry(NamedTuple):
    m: jax.Array       # running max         (B, KV, G, Sq)
    l: jax.Array       # running sum-exp     (B, KV, G, Sq)
    acc: jax.Array     # running weighted V  (B, KV, G, Sq, hd)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array, cfg: ArchConfig, *,
                      causal: bool, window: int = 0,
                      chunk: int = 1024) -> jax.Array:
    """Flash-style attention: scan over KV chunks, O(Sq*chunk) memory."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-10 ** 9)
    qg = (q.reshape(b, sq, kvh, g, hd).astype(F32)
          .transpose(0, 2, 3, 1, 4))                        # (B,KV,G,Sq,hd)
    kc = k.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 3, 2, 4)
    kpc = k_pos.reshape(n_chunks, chunk)

    init = _Carry(
        m=jnp.full((b, kvh, g, sq), NEG_INF, F32),
        l=jnp.zeros((b, kvh, g, sq), F32),
        acc=jnp.zeros((b, kvh, g, sq, hd), F32),
    )
    scale = 1.0 / (hd ** 0.5)

    def step(carry: _Carry, xs):
        kj, vj, kp = xs                                     # (B,KV,C,hd), (C,)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kj.astype(F32)) * scale
        s = _softcap(s, cfg.attn_logit_softcap)
        mask = make_mask(q_pos, kp, causal=causal, window=window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(carry.m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(carry.m - m_new)
        l_new = carry.l * corr + p.sum(-1)
        acc_new = carry.acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p, vj.astype(F32))
        return _Carry(m_new, l_new, acc_new), None

    carry, _ = jax.lax.scan(step, init, (kc, vc, kpc))
    o = carry.acc / jnp.maximum(carry.l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode path (one token vs. a cache)
# ---------------------------------------------------------------------------

def attention_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, cfg: ArchConfig, *,
                     window: int = 0) -> jax.Array:
    """q: (B,1,H,hd); caches: (B,S,KV,hd); cache_len: scalar or (B,) int.

    The caller writes the new token's K/V at position ``cache_len``
    first; attention then covers [0, cache_len] per sequence (static
    shapes, masked beyond).  Per-sequence lengths are what continuous
    batching serves from one compiled program.
    """
    b, _, h, hd = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32),
                        k_cache.astype(F32)) / (hd ** 0.5)
    logits = _softcap(logits, cfg.attn_logit_softcap)
    k_pos = jnp.arange(s)
    lens = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    valid = k_pos[None, :] <= lens[:, None]                  # (B,S)
    if window:
        valid &= k_pos[None, :] > lens[:, None] - window
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(F32))
    return o.reshape(b, 1, h, hd).astype(q.dtype)


def update_kv_cache(k_cache: jax.Array, v_cache: jax.Array, k: jax.Array,
                    v: jax.Array, cache_len: jax.Array,
                    uniform: bool = False):
    """Write one token's K/V at per-sequence position(s) ``cache_len``.

    ``uniform=True`` asserts every sequence sits at the same position
    (bulk decode benchmarks; synchronized batches) and uses a masked
    ``where``-update over the sequence dim.  Rationale (measured on
    mistral decode_32k, EXPERIMENTS.md §Perf cell C):

    * the general per-sequence path lowers to a scatter; a scatter whose
      operand is also read by attention in the same loop body makes XLA
      COPY the full stacked cache every layer (489 GiB/chip/step),
    * a dynamic-update-slice at a *traced* position into the
      ``model``-sharded sequence dim makes SPMD all-gather the cache
      (worse still: 5.3 s memory term),
    * the masked where is elementwise, shard-local on every mesh layout,
      and fuses with the attention read that already streams the cache.

    Continuous batching keeps the scatter path; it pays for generality
    only where generality is used.
    """
    if uniform:
        pos = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32),
                               (k_cache.shape[0],))[0]
        onpos = (jnp.arange(k_cache.shape[1]) == pos)[None, :, None, None]
        k_cache = jnp.where(onpos, k.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(onpos, v.astype(v_cache.dtype), v_cache)
        return k_cache, v_cache
    b = k_cache.shape[0]
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))

    def one(cache_b, new_b, p):
        return jax.lax.dynamic_update_slice(
            cache_b, new_b.astype(cache_b.dtype),
            (p, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))

    k_cache = jax.vmap(one)(k_cache, k, lens)
    v_cache = jax.vmap(one)(v_cache, v, lens)
    return k_cache, v_cache


def kv_cache_spec(cfg: ArchConfig, axes: Axes, batch: int,
                  tp_size_hint: int = 16) -> P:
    """PartitionSpec for a (L, B, S, KV, hd) cache.

    batch > 1: shard batch over the data axis.  batch == 1 (long-context
    decode): shard the *sequence* dim over data instead (ring layout).
    KV heads shard over model only when divisible.
    """
    _, kv_tp = _attn_tp(cfg, axes, tp_size_hint)
    if batch == 1:
        return P(None, None, axes.fsdp, kv_tp, None)
    return P(None, axes.batch if len(axes.batch) > 1 else axes.batch[0],
             None, kv_tp, None)
