"""State-space / recurrent blocks: Mamba (S6), mLSTM, sLSTM.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan of the Mamba paper
and the fused mLSTM kernels of xLSTM do not port; instead each recurrence
is expressed in a *chunkwise-parallel* form that maps onto the MXU:

* Mamba: ``lax.scan`` over sequence chunks; inside a chunk the diagonal
  recurrence runs as an ``associative_scan`` (log-depth, parallel).
* mLSTM: matrix-memory recurrence in the chunked linear-attention form --
  intra-chunk quadratic term (a small attention with decay weights, MXU-
  friendly) + inter-chunk state carry, with max-stabilized exponential
  gating carried exactly.
* sLSTM: memory mixing makes it sequential *by design* (the paper's own
  point); it runs as a ``lax.scan`` over time and is deliberately kept in
  the small minority of layers (xlstm-125m pattern).

Sharding: Mamba shards ``d_inner`` over the model axis (channel-wise
state independence makes this collective-free); mLSTM shards the value
head dim; sLSTM is replicated over model (tiny, recurrence is dense in
``hd``).  All shard only when divisible, per the §4 policy.

Decode: every block exposes ``*_decode_step`` updating O(1)-per-token
recurrent state -- this is what makes long_500k runnable for ssm/hybrid.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from .params import Axes, ParamDef, Schema

F32 = jnp.float32


def _tp_if(axes: Axes, dim: int, hint: int = 16):
    return axes.tp if (axes.tp and dim % hint == 0) else None


# ===========================================================================
# Mamba (S6 selective scan)
# ===========================================================================

def mamba_schema(cfg: ArchConfig, axes: Axes) -> Schema:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    tp = _tp_if(axes, inner)
    return {
        "in_proj": ParamDef((d, 2 * inner), P(axes.fsdp, tp)),
        "conv_w": ParamDef((cfg.ssm_conv, inner), P(None, tp), init="fan_in",
                           fan_in_axes=(0,)),
        "conv_b": ParamDef((inner,), P(tp), init="zeros"),
        "x_dbc": ParamDef((inner, 1 + 2 * n), P(tp, None)),   # -> dt, B, C
        "dt_bias": ParamDef((inner,), P(tp), init="zeros"),
        "a_log": ParamDef((inner, n), P(tp, None), init="ones"),
        "d_skip": ParamDef((inner,), P(tp), init="ones"),
        "out_proj": ParamDef((inner, d), P(tp, axes.fsdp)),
    }


def _mamba_gates(params: Schema, u: jax.Array, cfg: ArchConfig):
    """Shared front half: projections, conv, dt/B/C. u: (B,S,D)."""
    inner = params["conv_b"].shape[0]
    xz = jnp.einsum("bsd,de->bse", u, params["in_proj"],
                    preferred_element_type=F32)
    x, z = jnp.split(xz, 2, axis=-1)                         # (B,S,inner)
    # depthwise causal conv over seq
    k = cfg.ssm_conv
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    x = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][i].astype(F32)
            for i in range(k)) + params["conv_b"].astype(F32)
    x = jax.nn.silu(x)
    dbc = jnp.einsum("bsi,ie->bse", x, params["x_dbc"].astype(F32))
    n = cfg.ssm_state
    # dt: scalar-per-token broadcast to channels through the learned bias
    dt = jax.nn.softplus(dbc[..., 0:1] + params["dt_bias"].astype(F32))
    bmat = dbc[..., 1:1 + n]                                  # (B,S,N)
    cmat = dbc[..., 1 + n:]                                   # (B,S,N)
    a = -jnp.exp(params["a_log"].astype(F32))                 # (inner,N)
    return x, z, dt, bmat, cmat, a, inner


def mamba_apply(params: Schema, u: jax.Array, cfg: ArchConfig,
                chunk: int = 128) -> jax.Array:
    """Full-sequence selective scan. u: (B,S,D) -> (B,S,D).

    The C·h readout is fused INTO the chunk step so hidden states
    (B, S, inner, N) -- 16x the activation size at N=16 -- exist only one
    chunk at a time.  Before this fusion the full hidden stack dominated
    hymba-1.5b train_4k HBM traffic (EXPERIMENTS.md §Perf cell A);
    this is also how the Pallas ssm_scan kernel behaves (state stays in
    VMEM, only y leaves).
    """
    x, z, dt, bmat, cmat, a, inner = _mamba_gates(params, u, cfg)
    b_, s, _ = x.shape
    n = cfg.ssm_state
    # discretize: decay (B,S,inner,N), drive (B,S,inner,N)
    decay = jnp.exp(dt[..., None] * a[None, None])            # exp(dt*A)
    drive = (dt * x)[..., None] * bmat[:, :, None, :]         # dt*x*B

    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
        drive = jnp.pad(drive, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    dec_c = decay.reshape(b_, nc, chunk, inner, n).transpose(1, 0, 2, 3, 4)
    drv_c = drive.reshape(b_, nc, chunk, inner, n).transpose(1, 0, 2, 3, 4)
    cm_c = cmat.reshape(b_, nc, chunk, n).transpose(1, 0, 2, 3)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h0, xs):
        dec, drv, cm = xs                                     # (B,C,inner,N)
        aa, bb = jax.lax.associative_scan(combine, (dec, drv), axis=1)
        h = aa * h0[:, None] + bb                             # (B,C,inner,N)
        y = jnp.einsum("bcin,bcn->bci", h, cm)                # fused C·h
        return h[:, -1], y

    h0 = jnp.zeros((b_, inner, n), F32)
    _, ys = jax.lax.scan(chunk_step, h0, (dec_c, drv_c, cm_c))
    y = ys.transpose(1, 0, 2, 3).reshape(b_, nc * chunk, inner)[:, :s]
    y = y + x * params["d_skip"].astype(F32)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y.astype(u.dtype), params["out_proj"],
                     preferred_element_type=F32)
    return out.astype(u.dtype)


def mamba_state_shape(cfg: ArchConfig, batch: int) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    inner = cfg.ssm_expand * cfg.d_model
    return (batch, inner, cfg.ssm_state), (batch, cfg.ssm_conv - 1, inner)


def mamba_decode_step(params: Schema, u: jax.Array, state: jax.Array,
                      conv_state: jax.Array, cfg: ArchConfig):
    """One token. u: (B,1,D); state: (B,inner,N); conv: (B,k-1,inner)."""
    inner = params["conv_b"].shape[0]
    n = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", u, params["in_proj"],
                    preferred_element_type=F32)
    x, z = jnp.split(xz, 2, axis=-1)                          # (B,1,inner)
    window = jnp.concatenate([conv_state, x], axis=1)         # (B,k,inner)
    conv_state = window[:, 1:]
    x = jnp.einsum("bki,ki->bi", window, params["conv_w"].astype(F32)) \
        + params["conv_b"].astype(F32)
    x = jax.nn.silu(x)[:, None]                               # (B,1,inner)
    dbc = jnp.einsum("bsi,ie->bse", x, params["x_dbc"].astype(F32))
    dt = jax.nn.softplus(dbc[..., 0:1] + params["dt_bias"].astype(F32))
    bmat, cmat = dbc[..., 1:1 + n], dbc[..., 1 + n:]
    a = -jnp.exp(params["a_log"].astype(F32))
    decay = jnp.exp(dt[:, 0, :, None] * a[None])              # (B,inner,N)
    drive = (dt * x)[:, 0, :, None] * bmat[:, 0, None, :]
    state = decay * state + drive
    y = jnp.einsum("bin,bn->bi", state, cmat[:, 0])[:, None]
    y = y + x * params["d_skip"].astype(F32)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y.astype(u.dtype), params["out_proj"],
                     preferred_element_type=F32)
    return out.astype(u.dtype), state, conv_state


# ===========================================================================
# mLSTM (matrix memory, chunkwise-parallel with stabilized gating)
# ===========================================================================

def mlstm_schema(cfg: ArchConfig, axes: Axes) -> Schema:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    h = cfg.n_heads
    hd = inner // h
    tp = _tp_if(axes, hd)
    return {
        "up_proj": ParamDef((d, 2 * inner), P(axes.fsdp, None)),
        "wq": ParamDef((inner, h, hd), P(None, None, None)),
        "wk": ParamDef((inner, h, hd), P(None, None, None)),
        "wv": ParamDef((inner, h, hd), P(None, None, tp)),
        "w_if": ParamDef((inner, h, 2), P(None, None, None), init="small"),
        "b_if": ParamDef((h, 2), P(None, None), init="zeros"),
        "out_norm": ParamDef((inner,), P(None), init="ones"),
        "down_proj": ParamDef((inner, d), P(None, axes.fsdp)),
    }


def _mlstm_qkvg(params: Schema, u: jax.Array):
    xz = jnp.einsum("bsd,de->bse", u, params["up_proj"],
                    preferred_element_type=F32)
    x, z = jnp.split(xz, 2, axis=-1)                          # (B,S,inner)
    q = jnp.einsum("bsi,ihk->bshk", x, params["wq"].astype(F32))
    k = jnp.einsum("bsi,ihk->bshk", x, params["wk"].astype(F32))
    v = jnp.einsum("bsi,ihk->bshk", x, params["wv"].astype(F32))
    gates = jnp.einsum("bsi,ihg->bshg", x, params["w_if"].astype(F32)) \
        + params["b_if"].astype(F32)
    log_i = gates[..., 0]                                     # (B,S,H)
    log_f = jax.nn.log_sigmoid(gates[..., 1])
    return q, k, v, z, log_i, log_f


def mlstm_apply(params: Schema, u: jax.Array, cfg: ArchConfig,
                chunk: int = 128) -> jax.Array:
    """Chunked mLSTM. u: (B,S,D) -> (B,S,D)."""
    q, k, v, z, log_i, log_f = _mlstm_qkvg(params, u)
    b_, s, h, hd = q.shape
    hd_v = v.shape[-1]
    scale = hd ** -0.5
    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        padfn = lambda t, fill=0.0: jnp.pad(
            t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2),
            constant_values=fill)
        q, k, v = padfn(q), padfn(k), padfn(v)
        log_i = padfn(log_i, -1e30)     # padded tokens contribute nothing
        log_f = padfn(log_f, 0.0)

    def to_chunks(t):
        return t.reshape((b_, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)

    causal = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None])

    def chunk_step(carry, xs):
        c_in, n_in, m_in = carry           # (B,H,hd,hdv), (B,H,hd), (B,H)
        qj, kj, vj, li, lf = xs            # (B,C,H,*), (B,C,H)
        fcum = jnp.cumsum(lf, axis=1)                          # F_t (B,C,H)
        ftot = fcum[:, -1]                                     # (B,H)
        # decay exponents: d[t,j] = F_t - F_j + log i_j  for j <= t
        dmat = fcum[:, :, None] - fcum[:, None] + li[:, None]  # (B,t,j,H)
        m_intra = jnp.max(dmat, axis=2, initial=-1e30,
                          where=causal[None, :, :, None])      # (B,C,H)
        m_inter = fcum + m_in[:, None]                         # (B,C,H)
        m_t = jnp.maximum(m_intra, m_inter)
        # inter-chunk: q_t . C_in, decayed through the chunk prefix
        w_inter = jnp.exp(m_inter - m_t)                       # (B,C,H)
        h_inter = jnp.einsum("bchk,bhkv->bchv", qj * scale, c_in) \
            * w_inter[..., None]
        n_inter = jnp.einsum("bchk,bhk->bch", qj * scale, n_in) * w_inter
        # intra-chunk quadratic term with decay weights (MXU matmuls)
        w_intra = jnp.exp(dmat - m_t[:, :, None]) * causal[None, :, :, None]
        s_qk = jnp.einsum("bthk,bjhk->btjh", qj * scale, kj)
        h_intra = jnp.einsum("btjh,btjh,bjhv->bthv", s_qk, w_intra, vj)
        n_intra = jnp.einsum("btjh,btjh->bth", s_qk, w_intra)
        h_num = h_inter + h_intra
        n_den = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))
        h_out = h_num / n_den[..., None]
        # state update to chunk end
        m_out = jnp.maximum(ftot + m_in,
                            jnp.max(ftot[:, None] - fcum + li, axis=1))
        w_carry = jnp.exp(ftot + m_in - m_out)                 # (B,H)
        w_k = jnp.exp(ftot[:, None] - fcum + li - m_out[:, None])  # (B,C,H)
        c_out = c_in * w_carry[..., None, None] + jnp.einsum(
            "bchk,bchv->bhkv", kj * w_k[..., None], vj)
        n_out = n_in * w_carry[..., None] + jnp.einsum(
            "bchk,bch->bhk", kj, w_k)
        return (c_out, n_out, m_out), h_out

    c0 = jnp.zeros((b_, h, hd, hd_v), F32)
    n0 = jnp.zeros((b_, h, hd), F32)
    m0 = jnp.full((b_, h), -1e30, F32)
    _, hs = jax.lax.scan(chunk_step, (c0, n0, m0), (qc, kc, vc, lic, lfc))
    hs = hs.swapaxes(0, 1).reshape(b_, nc * chunk, h, hd_v)[:, :s]
    out = hs.reshape(b_, s, h * hd_v)
    out = out * jax.nn.silu(z)
    out = _rms(out) * params["out_norm"].astype(F32)
    return jnp.einsum("bsi,id->bsd", out.astype(u.dtype),
                      params["down_proj"],
                      preferred_element_type=F32).astype(u.dtype)


def _rms(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return x * jax.lax.rsqrt((x * x).mean(-1, keepdims=True) + eps)


def mlstm_state_shapes(cfg: ArchConfig, batch: int):
    inner = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    hd = inner // h
    return {"c": (batch, h, hd, hd), "n": (batch, h, hd), "m": (batch, h)}


def mlstm_decode_step(params: Schema, u: jax.Array, state: Dict[str, jax.Array],
                      cfg: ArchConfig):
    """One token with O(1) state. u: (B,1,D)."""
    q, k, v, z, log_i, log_f = _mlstm_qkvg(params, u)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                       # (B,H,hd)
    li, lf = log_i[:, 0], log_f[:, 0]                         # (B,H)
    scale = q.shape[-1] ** -0.5
    c, n, m = state["c"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    c = c * fw[..., None, None] + iw[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = n * fw[..., None] + iw[..., None] * k
    h_num = jnp.einsum("bhk,bhkv->bhv", q * scale, c)
    n_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q * scale, n)),
                        jnp.exp(-m_new))
    h_out = (h_num / n_den[..., None]).reshape(u.shape[0], 1, -1)
    out = h_out * jax.nn.silu(z)
    out = _rms(out) * params["out_norm"].astype(F32)
    out = jnp.einsum("bsi,id->bsd", out.astype(u.dtype), params["down_proj"],
                     preferred_element_type=F32).astype(u.dtype)
    return out, {"c": c, "n": n, "m": m_new}


# ===========================================================================
# sLSTM (scalar memory + memory mixing; sequential by design)
# ===========================================================================

def slstm_schema(cfg: ArchConfig, axes: Axes) -> Schema:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    return {
        "w_gates": ParamDef((d, 4, h, hd), P(axes.fsdp, None, None, None)),
        "r_gates": ParamDef((4, h, hd, hd), P(None, None, None, None),
                            init="fan_in", fan_in_axes=(2,)),
        "b_gates": ParamDef((4, h, hd), P(None, None, None), init="zeros"),
        "out_norm": ParamDef((d,), P(None), init="ones"),
        "out_proj": ParamDef((d, d), P(axes.fsdp, None)),
    }


def slstm_state_shapes(cfg: ArchConfig, batch: int):
    h = cfg.n_heads
    hd = cfg.d_model // h
    return {k: (batch, h, hd) for k in ("c", "n", "h", "m")}


def _slstm_cell(params: Schema, wx_t: jax.Array, state: Dict[str, jax.Array]):
    """wx_t: (B,4,H,hd) precomputed input projections."""
    r = params["r_gates"].astype(F32)
    rec = jnp.einsum("bhk,ghkl->bghl", state["h"], r)          # (B,4,H,hd)
    raw = wx_t + rec + params["b_gates"].astype(F32)
    li = raw[:, 0]
    lf = jax.nn.log_sigmoid(raw[:, 1])
    zg = jnp.tanh(raw[:, 2])
    og = jax.nn.sigmoid(raw[:, 3])
    m_new = jnp.maximum(lf + state["m"], li)
    fw = jnp.exp(lf + state["m"] - m_new)
    iw = jnp.exp(li - m_new)
    c = fw * state["c"] + iw * zg
    n = fw * state["n"] + iw
    h_new = og * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h_new, "m": m_new}


def slstm_apply(params: Schema, u: jax.Array, cfg: ArchConfig) -> jax.Array:
    b_, s, d = u.shape
    h, hd = cfg.n_heads, d // cfg.n_heads
    wx = jnp.einsum("bsd,dghk->bsghk", u.astype(F32),
                    params["w_gates"].astype(F32))
    state = {k: jnp.zeros((b_, h, hd), F32) for k in ("c", "n", "h")}
    state["m"] = jnp.full((b_, h, hd), -1e30, F32)

    def step(state, wx_t):
        new = _slstm_cell(params, wx_t, state)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))      # (S,B,H,hd)
    hs = hs.swapaxes(0, 1).reshape(b_, s, d)
    hs = _rms(hs) * params["out_norm"].astype(F32)
    return jnp.einsum("bsd,de->bse", hs.astype(u.dtype), params["out_proj"],
                      preferred_element_type=F32).astype(u.dtype)


def slstm_decode_step(params: Schema, u: jax.Array,
                      state: Dict[str, jax.Array], cfg: ArchConfig):
    b_, _, d = u.shape
    wx = jnp.einsum("bsd,dghk->bsghk", u.astype(F32),
                    params["w_gates"].astype(F32))[:, 0]
    new = _slstm_cell(params, wx, state)
    hs = new["h"].reshape(b_, 1, d)
    hs = _rms(hs) * params["out_norm"].astype(F32)
    out = jnp.einsum("bsd,de->bse", hs.astype(u.dtype), params["out_proj"],
                     preferred_element_type=F32).astype(u.dtype)
    return out, new
