"""Fault-tolerant trainer: step loop + DynIMS + checkpoint/restart.

One object wires the whole stack the way a pod deployment would:

* data: :class:`~repro.data.pipeline.DataPipeline` whose host shard
  cache is DynIMS-managed (the paper's contribution in the input path),
* control: one :class:`~repro.core.plane.MemoryPlane` ticked from the
  step loop (production runs it on its own thread at T=100 ms; the
  step-synchronous tick keeps tests deterministic),
* checkpointing: :class:`~repro.checkpoint.CheckpointManager`, restart
  via ``resume()`` -- the pipeline is sampled by step number, so restore
  is exact,
* runtime: heartbeats + straggler detection with the DynIMS squeeze
  escalation (runtime/straggler.py).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs.dynims import host_cache_params
from ..core.plane import MemoryPlane
from ..data.pipeline import DataPipeline
from ..models.transformer import Model
from ..runtime.fault import HeartbeatMonitor
from ..runtime.straggler import StragglerDetector
from .step import TrainStepConfig, TrainState, build_train_step, \
    init_train_state


@dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro-ckpt"
    async_checkpoint: bool = False
    log_every: int = 10
    dynims_interval_steps: int = 1      # control ticks per step


class Trainer:
    def __init__(self, model: Model, pipeline: DataPipeline,
                 step_cfg: TrainStepConfig, cfg: TrainerConfig,
                 plane: Optional[MemoryPlane] = None,
                 jit: bool = True):
        self.model = model
        self.pipeline = pipeline
        self.cfg = cfg
        self.step_cfg = step_cfg
        self.plane = plane
        self.ckpt = CheckpointManager(cfg.checkpoint_dir,
                                      async_save=cfg.async_checkpoint)
        self.heartbeats = HeartbeatMonitor()
        self.stragglers = StragglerDetector(
            squeeze_cb=self._squeeze_worker)
        step_fn = build_train_step(model, step_cfg)
        self._step_fn = jax.jit(step_fn) if jit else step_fn
        self.metrics_log: List[Dict[str, float]] = []
        self._squeezed: Dict[str, float] = {}

    # ---- DynIMS coupling ---------------------------------------------------
    def _squeeze_worker(self, worker: str, factor: float) -> None:
        """Straggler mitigation step 1: shrink that worker's cache."""
        self._squeezed[worker] = factor
        if self.plane is not None:
            self.plane.squeeze(worker, factor)

    # ---- main loop ------------------------------------------------------------
    def fit(self, params, state: Optional[TrainState] = None,
            start_step: int = 0):
        state = state or init_train_state(params, self.step_cfg)
        worker = "worker-0"
        self.heartbeats.register(worker)
        for step in range(start_step, self.cfg.steps):
            t0 = time.monotonic()
            batch = self.pipeline.batch(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            params, state, metrics = self._step_fn(params, state, batch)
            if self.plane is not None and (
                    step % self.cfg.dynims_interval_steps == 0):
                self.plane.tick()
            dt = time.monotonic() - t0
            self.heartbeats.heartbeat(worker)
            self.stragglers.record(worker, dt)
            if step % self.cfg.log_every == 0 or step == self.cfg.steps - 1:
                row = {k: float(np.asarray(v)) for k, v in metrics.items()}
                row.update(step=step, wall_s=dt,
                           cache_hit=self.pipeline.hit_ratio)
                self.metrics_log.append(row)
            if (step + 1) % self.cfg.checkpoint_every == 0 \
                    or step == self.cfg.steps - 1:
                self.ckpt.save({"params": params, "opt": state.adam,
                                "step": step + 1}, step + 1)
        self.ckpt.wait()
        return params, state

    # ---- restart --------------------------------------------------------------
    def resume(self, params, state: Optional[TrainState] = None):
        """Restore the newest complete checkpoint and continue."""
        state = state or init_train_state(params, self.step_cfg)
        tree_like = {"params": params, "opt": state.adam, "step": 0}
        restored, step = self.ckpt.restore_latest(tree_like)
        if restored is None:
            return self.fit(params, state, start_step=0)
        params = restored["params"]
        state = TrainState(adam=restored["opt"],
                           compression=state.compression)
        return self.fit(params, state, start_step=int(restored["step"]))
