"""Train-step builder: CE + z-loss, microbatched grad accumulation,
global-norm clipping, AdamW, optional int8 error-feedback compression.

The returned function is pure and jit/pjit-friendly:

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)

Microbatching: the global batch is split into ``microbatches`` equal
slices scanned sequentially with f32 gradient accumulation -- the
activation-memory knob that makes mistral-large-123b train_4k fit
(DESIGN.md §4 / EXPERIMENTS.md §Perf).

Compression: with ``compress=True`` the optimizer consumes int8-
quantized gradients with error feedback; the residual rides in
``opt_state``.  On the multi-pod mesh the quantized payload is what the
pod-axis reduction moves (launch/train.py wires the shard_map variant).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.transformer import Model
from ..optim.adamw import AdamWState, adamw_init, adamw_update
from ..optim.compress import (CompressionState, compress_decompress,
                              compression_init)
from ..optim.schedules import linear_warmup_cosine

F32 = jnp.float32


@dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    clip_norm: float = 1.0
    compress: bool = False
    schedule: Callable = linear_warmup_cosine


class TrainState(NamedTuple):
    adam: AdamWState
    compression: Optional[CompressionState]


def init_train_state(params, cfg: TrainStepConfig) -> TrainState:
    return TrainState(
        adam=adamw_init(params),
        compression=compression_init(params) if cfg.compress else None,
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x.astype(F32) * scale), tree), norm


def build_train_step(model: Model, cfg: TrainStepConfig):
    """-> step_fn(params, state, batch) for pjit."""

    def loss_fn(params, micro):
        loss, parts = model.loss(params, micro)
        return loss, parts

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        n = cfg.microbatches
        if n == 1:
            (loss, parts), grads = grad_fn(params, batch)
            return grads, loss, parts
        split = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        def micro_step(acc, mb):
            g_acc, l_acc = acc
            (loss, _), grads = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(F32) / n, g_acc, grads)
            return (g_acc, l_acc + loss / n), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        (grads, loss), _ = jax.lax.scan(
            micro_step, (zeros, jnp.zeros((), F32)), split)
        return grads, loss, {"ce": loss, "aux": jnp.zeros((), F32)}

    def step_fn(params, state: TrainState, batch
                ) -> Tuple[object, TrainState, Dict[str, jax.Array]]:
        grads, loss, parts = accumulate(params, batch)
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        comp = state.compression
        if cfg.compress:
            grads, comp = compress_decompress(grads, comp)
        lr = cfg.schedule(state.adam.step, peak_lr=cfg.peak_lr,
                          warmup_steps=cfg.warmup_steps,
                          total_steps=cfg.total_steps)
        params, adam = adamw_update(
            grads, state.adam, params, lr=lr, b1=cfg.b1, b2=cfg.b2,
            weight_decay=cfg.weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": adam.step, **parts}
        return params, TrainState(adam=adam, compression=comp), metrics

    return step_fn
