"""Training substrate: step builder + fault-tolerant trainer loop."""

from .step import TrainStepConfig, build_train_step
from .trainer import Trainer, TrainerConfig

__all__ = ["TrainStepConfig", "Trainer", "TrainerConfig",
           "build_train_step"]
