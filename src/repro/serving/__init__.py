"""Serving substrate: continuous batching over a DynIMS-managed KV pool."""

from .engine import Request, ServingEngine, ServingConfig

__all__ = ["Request", "ServingConfig", "ServingEngine"]
