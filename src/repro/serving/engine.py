"""Continuous-batching serving engine over a DynIMS-managed KV pool.

The paper's architecture in the serving path: HBM is the contended
resource; the *compute tenant* is the model's weights + activation
working set, the *storage tenant* is the KV cache.  The
:class:`~repro.core.store.KVBlockPool` bookkeeps block grants; a
:class:`~repro.core.plane.MemoryPlane` (device monitor -> controller)
resizes the pool each interval, and a shrink preempts whole sequences,
which the engine transparently requeues (their progress is kept: tokens
generated so far become part of the prompt on re-admission).  The
engine declares its pool to the plane at construction and ticks it once
per decode step; all bus/controller wiring stays inside the plane.

Mechanics:

* fixed ``max_batch`` slots; one compiled ``decode_step`` serves every
  mix of sequence progress (per-slot positions),
* admission: a request needs pool blocks for prompt + headroom; denied
  admission leaves it queued,
* each generated token may claim a new block (every ``block_tokens``);
  failure to claim -> self-preemption back to the queue,
* prompt ingestion streams through the same decode step (exact for all
  families, incl. recurrent state).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.monitor import DeviceMemoryMonitor, MemoryMonitor
from ..core.plane import MemoryPlane, StoreSpec
from ..core.store import KVBlockPool
from ..models import decode as D
from ..models.transformer import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (len,) int32
    max_new_tokens: int
    output: List[int] = field(default_factory=list)
    preemptions: int = 0

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens

    @property
    def tokens_so_far(self) -> np.ndarray:
        return np.concatenate([self.prompt,
                               np.asarray(self.output, np.int32)])


@dataclass
class ServingConfig:
    max_batch: int = 4
    max_len: int = 256
    block_tokens: int = 16
    greedy: bool = True
    cache_dtype: str = "bfloat16"


@dataclass
class _Slot:
    request: Optional[Request] = None
    ingested: int = 0                    # prompt tokens fed so far

    @property
    def free(self) -> bool:
        return self.request is None


class ServingEngine:
    def __init__(self, model: Model, params, cfg: ServingConfig,
                 pool: Optional[KVBlockPool] = None,
                 plane: Optional[MemoryPlane] = None,
                 node: str = "serve0",
                 monitor: Optional[MemoryMonitor] = None,
                 jit: bool = True):
        self.model = model
        self.params = params
        self.cfg = cfg
        kv_bytes = self._block_bytes()
        n_blocks = cfg.max_batch * (cfg.max_len // cfg.block_tokens)
        self.pool = pool or KVBlockPool("kv-pool", n_blocks, kv_bytes)
        self.plane = plane
        self.node = node
        if plane is not None:
            # Declare the pool to the plane: per-chip HBM monitor unless
            # the caller supplies one (tests use a SimulatedMonitor).
            monitor = monitor or DeviceMemoryMonitor(
                jax.devices()[0], node=node,
                storage_used_fn=self.pool.used)
            plane.attach(
                node, monitor,
                stores=(StoreSpec(self.pool, self.pool.total_blocks
                                  * self.pool.block_bytes),))
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.slots = [_Slot() for _ in range(cfg.max_batch)]
        self._rid = itertools.count()
        self.state = D.init_state(model, cfg.max_batch, cfg.max_len,
                                  cache_dtype=cfg.cache_dtype)
        # per-leaf batch axis, found by diffing schema shapes at two batch
        # sizes (stack dims can numerically collide with max_batch)
        s1 = D.state_schema(model, 1, cfg.max_len)
        sN = D.state_schema(model, cfg.max_batch, cfg.max_len)
        from ..models.params import is_leaf as _is_leaf
        self._batch_axis_tree = jax.tree.map(
            lambda a, b: next((i for i, (x, y) in enumerate(
                zip(a.shape, b.shape)) if x != y), None),
            s1, sN, is_leaf=_is_leaf)
        self._step = jax.jit(
            lambda p, s, t: D.decode_step(model, p, s, t)) if jit else (
            lambda p, s, t: D.decode_step(model, p, s, t))
        self.steps = 0

    def _block_bytes(self) -> float:
        cfg = self.model.cfg
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * 2   # k+v bf16
        layers = cfg.n_layers
        return float(self.cfg.block_tokens * per_tok * layers)

    # ---- client API ----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return rid

    def run_until_drained(self, max_steps: int = 100_000) -> Dict[int, Request]:
        while (self.queue or any(not s.free for s in self.slots)):
            self.step()
            if self.steps >= max_steps:
                raise RuntimeError("serving engine did not drain")
        return self.finished

    # ---- engine step ------------------------------------------------------------
    def step(self) -> None:
        self.steps += 1
        self._handle_preemptions()
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            # Still tick the plane: a fully-preempted engine depends on
            # the controller re-granting pool capacity to admit again.
            if self.plane is not None:
                self.plane.tick()
            return
        tokens, feeding = self._next_tokens()
        logits, self.state = self._step(self.params, self.state,
                                        jnp.asarray(tokens))
        self._consume(logits, feeding)
        if self.plane is not None:
            self.plane.tick()

    # ---- internals -----------------------------------------------------------------
    def _handle_preemptions(self) -> None:
        for seq_id in self.pool.drain_preempted():
            slot = self.slots[seq_id]
            if slot.request is not None:
                req = slot.request
                req.preemptions += 1
                self._release_slot(seq_id, requeue=True)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            req = self.queue[0]
            need = (len(req.tokens_so_far) // self.cfg.block_tokens) + 1
            if self.pool.num_free_blocks() < need:
                break                      # honor queue order (no starvation)
            for _ in range(need):
                assert self.pool.alloc_block(i) is not None
            self.queue.pop(0)
            slot.request = req
            slot.ingested = 0
            self._reset_slot_state(i)

    def _next_tokens(self):
        """Pick the token each active slot feeds this step."""
        tokens = np.zeros((self.cfg.max_batch, 1), np.int32)
        feeding = {}
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.request
            seq = req.tokens_so_far
            if slot.ingested < len(seq):
                tokens[i, 0] = seq[slot.ingested]
                feeding[i] = "prompt"
            else:
                feeding[i] = "generate"
                tokens[i, 0] = seq[-1]
        return tokens, feeding

    def _consume(self, logits, feeding) -> None:
        next_tok = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, mode in feeding.items():
            slot = self.slots[i]
            req = slot.request
            self.pool.touch(i)
            slot.ingested += 1
            if mode == "prompt":
                if slot.ingested < len(req.tokens_so_far):
                    continue
                # prompt done; the argmax after the last prompt token is
                # the first generated token
            req.output.append(int(next_tok[i]))
            if slot.ingested % self.cfg.block_tokens == 0:
                if self.pool.alloc_block(i) is None:
                    req.preemptions += 1
                    self._release_slot(i, requeue=True)
                    continue
            if req.done or slot.ingested >= self.cfg.max_len - 1:
                self._release_slot(i, requeue=False)

    def _reset_slot_state(self, i: int) -> None:
        """Reset one slot: position to 0 and (for recurrent families)
        restore its recurrent state to the init values.  KV cache
        contents need no clearing -- they are masked by position."""
        def reset(leaf, fresh, axis):
            if axis is None:
                return leaf
            idx = [slice(None)] * leaf.ndim
            idx[axis] = i
            return leaf.at[tuple(idx)].set(fresh[tuple(idx)])

        if self.model.cfg.family in ("ssm", "hybrid"):
            if not hasattr(self, "_fresh_state"):
                self._fresh_state = D.init_state(
                    self.model, self.cfg.max_batch, self.cfg.max_len,
                    cache_dtype=self.cfg.cache_dtype)
            self.state = jax.tree.map(reset, self.state,
                                      self._fresh_state,
                                      self._batch_axis_tree)
        else:
            pos = np.asarray(self.state["pos"]).copy()
            pos[i] = 0
            self.state = dict(self.state)
            self.state["pos"] = jnp.asarray(pos)

    def _release_slot(self, i: int, requeue: bool) -> None:
        req = self.slots[i].request
        self.slots[i] = _Slot()
        self.pool.free_seq(i)
        if requeue and req is not None:
            self.queue.insert(0, req)
        elif req is not None:
            self.finished[req.rid] = req

    # ---- metrics ----------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "steps": self.steps,
            "finished": len(self.finished),
            "queued": len(self.queue),
            "active": sum(not s.free for s in self.slots),
            "pool_free_blocks": self.pool.num_free_blocks(),
            "pool_capacity_bytes": self.pool.capacity(),
            "preemptions": sum(r.preemptions
                               for r in self.finished.values())
            + sum(r.preemptions for r in self.queue),
        }
